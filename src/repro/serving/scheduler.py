"""Microbatching scheduler: coalesce concurrent queries into one batch.

Point queries arrive one at a time but are cheapest answered together:
a batch shares row fetches (the provider is called once per distinct
vertex per batch), shares pair intersections (canonical dedup across
queries), and amortizes kernel/vectorization overhead over the whole
padded batch. The scheduler

- queues submitted queries with their arrival timestamp,
- drains them in windows of at most ``max_batch`` through
  ``QueryEngine.execute_batch``, and
- stamps each result with its submit-to-completion latency, feeding the
  p50/p99 ``LatencyRecorder``.

``max_batch=1`` degenerates to one-query-at-a-time serving — the
baseline the serving benchmark compares against.
"""
from __future__ import annotations

import time
from typing import List, Sequence

from .engine import QueryEngine
from .metrics import LatencyRecorder, LatencySummary
from .requests import Query, QueryResult

__all__ = ["MicrobatchScheduler"]


class MicrobatchScheduler:
    def __init__(self, engine: QueryEngine, *, max_batch: int = 64):
        assert max_batch >= 1
        self.engine = engine
        self.max_batch = int(max_batch)
        self._pending: List[tuple] = []  # (query, t_submit)
        self.recorder = LatencyRecorder()
        self.n_batches = 0

    # ---------------- request path ----------------
    def submit(self, query: Query) -> None:
        self._pending.append((query, time.perf_counter()))

    def submit_many(self, queries: Sequence[Query]) -> None:
        t = time.perf_counter()
        self._pending.extend((q, t) for q in queries)

    @property
    def pending(self) -> int:
        return len(self._pending)

    def flush(self) -> List[QueryResult]:
        """Drain the queue in ``max_batch`` windows; returns all results
        in submission order."""
        out: List[QueryResult] = []
        while self._pending:
            chunk = self._pending[: self.max_batch]
            t0 = time.perf_counter()
            results = self.engine.execute_batch([q for q, _ in chunk])
            t1 = time.perf_counter()
            # dequeue only after success: an engine error must leave the
            # chunk queued (visible, retryable), not silently dropped
            del self._pending[: self.max_batch]
            self.recorder.record_wall(t1 - t0)
            self.n_batches += 1
            for (q, t_sub), r in zip(chunk, results):
                r.latency_s = t1 - t_sub
                self.recorder.record(r.latency_s)
            out.extend(results)
        return out

    def run(self, queries: Sequence[Query]) -> List[QueryResult]:
        """Closed-loop convenience: submit all, drain to completion."""
        self.submit_many(queries)
        return self.flush()

    def latency_summary(self) -> LatencySummary:
        return self.recorder.summary()
