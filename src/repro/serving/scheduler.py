"""Microbatching scheduler: coalesce concurrent queries into one batch.

Point queries arrive one at a time but are cheapest answered together:
a batch shares row fetches (the provider is called once per distinct
vertex per batch), shares pair intersections (canonical dedup across
queries), and amortizes kernel/vectorization overhead over the whole
padded batch. The scheduler

- queues submitted queries with their arrival timestamp,
- drains them in windows of at most ``max_batch`` through
  ``QueryEngine.execute_batch``, and
- stamps each result with its submit-to-completion latency, feeding the
  p50/p99 ``LatencyRecorder``.

Two drain policies coexist:

- ``flush()`` — the closed-loop drain: empty the whole queue now
  (callers that own the loop, e.g. the launchers and benchmarks).
- ``poll()`` — deadline-aware batching for open-loop serving: a window
  dispatches when it is *full* (``max_batch``), when the **oldest
  pending query has waited ``max_wait`` seconds** (the latency deadline
  — without it a trickle of requests would wait forever for a full
  window), when an **SLO deadline is imminent** (an ``SLOPolicy``
  stamps each query ``t_submit + budget(class)``; the window goes out
  ``headroom_s`` before the most urgent one), or when an **urgent**
  query is pending (priority flush: ``submit(q, urgent=True)``).
  Otherwise ``poll`` returns nothing and requests keep coalescing.

**EDF window selection** — with an SLO policy attached, each window
takes the ``max_batch`` pending queries with the *earliest deadlines*
(stable on submit time), not the oldest submissions: a late-arriving
tight-deadline query jumps a queue of loose-deadline ones. Without a
policy, FIFO order is unchanged.

**Admission control / load shedding** — an overloaded open-loop service
must reject work it cannot serve in time, or every queued query's
latency collapses together:

- ``quotas`` (a ``TenantQuotas``) rate-limits per tenant at submit:
  an empty token bucket sheds with reason ``"quota"`` before the query
  can occupy queue depth;
- ``max_queue`` bounds the pending depth: a submit past it is rejected
  immediately (``submit`` returns False, reason ``"depth"``);
- ``shed_wait`` bounds staleness at dispatch: ``poll()`` drops pending
  queries that have already waited past it (reason ``"deadline"``);
- with an SLO policy, a query whose *class* deadline has strictly
  passed is shed with reason ``"slo"`` — under overload, tight-budget
  classes shed first, which is the policy expressing itself.

All four feed the ``shed``/``shed_rate`` counters (and per-class
``shed_by_class``) in the latency summary.

``max_batch=1`` degenerates to one-query-at-a-time serving — the
baseline the serving benchmark compares against. The clock is
injectable so deadline behavior is testable without sleeping, and
``submit(q, at=...)`` lets an open-loop generator stamp the query with
its schedule arrival time even when the submit call itself runs late
(backlogged server) — that difference IS the queueing delay.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

from ..obs import trace as obs_trace
from .engine import QueryEngine
from .metrics import LatencyRecorder, LatencySummary
from .requests import Query, QueryResult

__all__ = ["MicrobatchScheduler"]


def _slo_class(q: Query) -> str:
    """Latency class label for per-SLO breakdowns (the query kind)."""
    return q.kind.name.lower()


@dataclasses.dataclass
class _Pending:
    """One queued query with its admission-time metadata."""

    query: Query
    t_submit: float
    urgent: bool = False
    deadline: Optional[float] = None  # absolute SLO deadline (None: no SLO)


class MicrobatchScheduler:
    def __init__(
        self,
        engine: QueryEngine,
        *,
        max_batch: int = 64,
        max_wait: Optional[float] = None,
        max_queue: Optional[int] = None,
        shed_wait: Optional[float] = None,
        clock: Optional[Callable[[], float]] = None,
        slo=None,  # Optional[traffic.SLOPolicy]
        quotas=None,  # Optional[traffic.TenantQuotas]
    ):
        assert max_batch >= 1
        assert max_wait is None or max_wait >= 0.0
        assert max_queue is None or max_queue >= 1
        assert shed_wait is None or shed_wait >= 0.0
        if shed_wait is not None and max_wait is not None:
            # strict: _shed_stale runs before the due check with >=
            # comparisons, so equality would shed exactly the queries
            # the deadline flush exists to serve
            assert shed_wait > max_wait, (
                "shed_wait must exceed max_wait, or queries the "
                "deadline drain promises to serve get shed instead"
            )
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_wait = max_wait
        self.max_queue = None if max_queue is None else int(max_queue)
        self.shed_wait = shed_wait
        self.slo = slo
        self.quotas = quotas
        self._clock = clock or time.perf_counter
        self._pending: List[_Pending] = []
        self._n_urgent = 0
        self.recorder = LatencyRecorder()
        self.n_batches = 0
        self.n_deadline_flushes = 0
        self.n_priority_flushes = 0
        self.n_slo_flushes = 0
        self.n_shed_depth = 0
        self.n_shed_deadline = 0
        self.n_shed_slo = 0
        self.n_shed_quota = 0

    # ---------------- request path ----------------
    def _admit(self, query: Query, t: float, urgent: bool) -> bool:
        """Shared admission path: quota, then depth, then enqueue."""
        cls = _slo_class(query)
        if self.quotas is not None and query.tenant:
            if not self.quotas.admit(query.tenant, t):
                self.n_shed_quota += 1
                self.recorder.record_shed("quota", cls=cls)
                return False
        if self.max_queue is not None and len(self._pending) >= self.max_queue:
            self.n_shed_depth += 1
            self.recorder.record_shed("depth", cls=cls)
            return False
        deadline = self.slo.deadline(cls, t) if self.slo is not None else None
        self._pending.append(_Pending(query, t, bool(urgent), deadline))
        if urgent:
            self._n_urgent += 1
        return True

    def submit(self, query: Query, *, urgent: bool = False,
               at: Optional[float] = None) -> bool:
        """Queue one query. Returns False (and records a shed with the
        rejecting reason: ``"quota"`` for an exhausted tenant bucket,
        ``"depth"`` for a full queue) when admission fails — the
        caller's signal to back off or retry elsewhere.

        ``at`` stamps the query's *arrival* time (open-loop generators
        replaying a schedule); default is the clock's now.
        """
        t = self._clock() if at is None else float(at)
        return self._admit(query, t, urgent)

    def submit_many(self, queries: Sequence[Query]) -> int:
        """Queue many at one timestamp; returns how many were admitted
        (the rest shed, by reason)."""
        t = self._clock()
        return sum(1 for q in queries if self._admit(q, t, False))

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ---------------- drain policies ----------------
    def _due(self, now: float) -> Optional[str]:
        """Why the front window should dispatch now (None: keep waiting)."""
        if not self._pending:
            return None
        if len(self._pending) >= self.max_batch:
            return "full"
        if self._n_urgent:
            return "urgent"
        if self.slo is not None:
            dmin = min(p.deadline for p in self._pending)
            if now >= dmin - self.slo.headroom_s:
                return "slo"
        if self.max_wait is not None and (
            now - self._pending[0].t_submit >= self.max_wait
        ):
            return "deadline"
        return None

    def next_due_at(self) -> Optional[float]:
        """Earliest future time at which the queue becomes due, or None
        when no time-based trigger exists (queue empty, or neither
        ``max_wait`` nor an SLO policy is set). Open-loop drains advance
        a virtual clock to this point instead of busy-waiting."""
        if not self._pending:
            return None
        if len(self._pending) >= self.max_batch or self._n_urgent:
            return self._clock()
        cands = []
        if self.slo is not None:
            cands.append(min(p.deadline for p in self._pending)
                         - self.slo.headroom_s)
        if self.max_wait is not None:
            cands.append(self._pending[0].t_submit + self.max_wait)
        return min(cands) if cands else None

    def _peek_window(self) -> List[_Pending]:
        """Select (without removing) the next window. FIFO without an
        SLO policy; EDF (earliest absolute deadline, stable on submit
        order) with one — a full queue serves the most urgent work
        first. Selection previews so an engine error leaves the window
        queued (visible, retryable), not silently dropped; ``_remove``
        commits after success."""
        if self.slo is None or len(self._pending) <= 1:
            return self._pending[: self.max_batch]
        order = sorted(range(len(self._pending)),
                       key=lambda i: (self._pending[i].deadline, i))
        return [self._pending[i] for i in sorted(order[: self.max_batch])]

    def _record_results(self, chunk: List[_Pending], results, t0, t1):
        self.recorder.record_wall(t1 - t0)
        self.n_batches += 1
        for p, r in zip(chunk, results):
            r.latency_s = t1 - p.t_submit
            self.recorder.record(
                r.latency_s, cls=_slo_class(p.query),
                deadline_s=(None if p.deadline is None
                            else p.deadline - p.t_submit),
            )
        obs_trace.counter("queue_depth", len(self._pending))

    def _drain_window(self) -> List[QueryResult]:
        chunk = self._peek_window()
        t0 = self._clock()
        with obs_trace.span("scheduler_flush", cat="serving",
                            n=len(chunk)):
            results = self.engine.execute_batch([p.query for p in chunk])
        t1 = self._clock()
        self._remove(chunk)
        self._record_results(chunk, results, t0, t1)
        return results

    def _remove(self, chunk: List[_Pending]) -> None:
        taken = set(map(id, chunk))
        self._pending = [p for p in self._pending if id(p) not in taken]
        self._n_urgent -= sum(1 for p in chunk if p.urgent)

    def flush(self) -> List[QueryResult]:
        """Drain the queue in ``max_batch`` windows; returns all results
        in dispatch order (submission order without an SLO policy, EDF
        order with one). When the engine is a pipelined SPMD engine
        (``engine.pipeline``), the host pack + collective launch of
        window k+1 overlaps window k's in-flight device intersect —
        ``end_batch`` is the only device sync (the trace's
        ``spmd_overlap_wait``). The control plane stays sequential
        host-side, so pipelined and unpipelined drains are bit-exact."""
        if getattr(self.engine, "pipeline", False):
            return self._flush_pipelined()
        out: List[QueryResult] = []
        while self._pending:
            out.extend(self._drain_window())
        return out

    # ---------------- pipelined drain ----------------
    def _begin_window(self) -> tuple:
        """Dispatch the front window without waiting on the device.
        The ``scheduler_flush`` span covers only the host-side begin —
        keeping spans disjoint per lane (the wait is its own span), so
        the exported trace stays well-nested under overlap."""
        chunk = self._peek_window()
        t0 = self._clock()
        with obs_trace.span("scheduler_flush", cat="serving",
                            n=len(chunk), pipelined=True):
            inflight = self.engine.begin_batch([p.query for p in chunk])
        # the control plane (cache admission, serve matrix, the
        # measured-vs-modeled reconciliation) completed inside
        # begin_batch — the chunk is committed; only device counts
        # remain outstanding. A begin error leaves the chunk queued.
        self._remove(chunk)
        return chunk, inflight, t0

    def _finish_window(self, chunk, inflight, t0) -> List[QueryResult]:
        results = self.engine.end_batch(inflight)
        t1 = self._clock()
        self._record_results(chunk, results, t0, t1)
        return results

    def _flush_pipelined(self) -> List[QueryResult]:
        """Double-buffered drain: begin window k+1 before finishing
        window k, so at most one microbatch is in flight on device
        while the next one packs on host."""
        out: List[QueryResult] = []
        prev = None
        while self._pending or prev is not None:
            nxt = self._begin_window() if self._pending else None
            if prev is not None:
                out.extend(self._finish_window(*prev))
            prev = nxt
        return out

    def _shed_stale(self, now: float) -> None:
        """Drop pending queries that can no longer be served usefully:
        past ``shed_wait`` (reason ``"deadline"``) or, with an SLO
        policy, strictly past their class deadline (reason ``"slo"`` —
        strict, so a query AT its deadline still rides the flush that
        the ``"slo"`` due-reason triggers for it)."""
        if (self.shed_wait is None and self.slo is None) or not self._pending:
            return
        keep: List[_Pending] = []
        for p in self._pending:
            if self.shed_wait is not None and now - p.t_submit >= self.shed_wait:
                reason = "deadline"
                self.n_shed_deadline += 1
            elif p.deadline is not None and now > p.deadline:
                reason = "slo"
                self.n_shed_slo += 1
            else:
                keep.append(p)
                continue
            self.recorder.record_shed(reason, cls=_slo_class(p.query))
            if p.urgent:
                self._n_urgent -= 1
        if len(keep) != len(self._pending):
            self._pending = keep

    def poll(self) -> List[QueryResult]:
        """Deadline-aware drain with load shedding: dispatch windows
        only while one is due (full / urgent pending / an SLO deadline
        within headroom / oldest past ``max_wait``); queries already
        stale past ``shed_wait`` or their class deadline are
        rejected-with-reason instead of served; otherwise return
        nothing and let requests keep coalescing."""
        out: List[QueryResult] = []
        while True:
            now = self._clock()
            self._shed_stale(now)
            reason = self._due(now)
            if reason is None:
                return out
            if reason == "deadline":
                self.n_deadline_flushes += 1
            elif reason == "urgent":
                self.n_priority_flushes += 1
            elif reason == "slo":
                self.n_slo_flushes += 1
            out.extend(self._drain_window())

    def run(self, queries: Sequence[Query]) -> List[QueryResult]:
        """Closed-loop convenience: submit all, drain to completion."""
        self.submit_many(queries)
        return self.flush()

    def latency_summary(self) -> LatencySummary:
        return self.recorder.summary()
