"""Microbatching scheduler: coalesce concurrent queries into one batch.

Point queries arrive one at a time but are cheapest answered together:
a batch shares row fetches (the provider is called once per distinct
vertex per batch), shares pair intersections (canonical dedup across
queries), and amortizes kernel/vectorization overhead over the whole
padded batch. The scheduler

- queues submitted queries with their arrival timestamp,
- drains them in windows of at most ``max_batch`` through
  ``QueryEngine.execute_batch``, and
- stamps each result with its submit-to-completion latency, feeding the
  p50/p99 ``LatencyRecorder``.

Two drain policies coexist:

- ``flush()`` — the closed-loop drain: empty the whole queue now
  (callers that own the loop, e.g. the launchers and benchmarks).
- ``poll()`` — deadline-aware batching for open-loop serving: a window
  dispatches when it is *full* (``max_batch``), when the **oldest
  pending query has waited ``max_wait`` seconds** (the latency deadline
  — without it a trickle of requests would wait forever for a full
  window), or when an **urgent** query is pending (priority flush:
  ``submit(q, urgent=True)`` dispatches the current window immediately,
  batching whatever happens to be queued in front of it). Otherwise
  ``poll`` returns nothing and requests keep coalescing.

**Admission control / load shedding** — an overloaded open-loop service
must reject work it cannot serve in time, or every queued query's
latency collapses together:

- ``max_queue`` bounds the pending depth: a submit past it is rejected
  immediately (``submit`` returns False, reason ``"depth"``);
- ``shed_wait`` bounds staleness at dispatch: ``poll()`` drops pending
  queries that have already waited past it (reason ``"deadline"``)
  instead of serving answers nobody is waiting for anymore.

Both feed the ``shed``/``shed_rate`` counters in the latency summary.

``max_batch=1`` degenerates to one-query-at-a-time serving — the
baseline the serving benchmark compares against. The clock is
injectable so deadline behavior is testable without sleeping.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence

from ..obs import trace as obs_trace
from .engine import QueryEngine
from .metrics import LatencyRecorder, LatencySummary
from .requests import Query, QueryResult

__all__ = ["MicrobatchScheduler"]


def _slo_class(q: Query) -> str:
    """Latency class label for per-SLO breakdowns (the query kind)."""
    return q.kind.name.lower()


class MicrobatchScheduler:
    def __init__(
        self,
        engine: QueryEngine,
        *,
        max_batch: int = 64,
        max_wait: Optional[float] = None,
        max_queue: Optional[int] = None,
        shed_wait: Optional[float] = None,
        clock: Optional[Callable[[], float]] = None,
    ):
        assert max_batch >= 1
        assert max_wait is None or max_wait >= 0.0
        assert max_queue is None or max_queue >= 1
        assert shed_wait is None or shed_wait >= 0.0
        if shed_wait is not None and max_wait is not None:
            # strict: _shed_stale runs before the due check with >=
            # comparisons, so equality would shed exactly the queries
            # the deadline flush exists to serve
            assert shed_wait > max_wait, (
                "shed_wait must exceed max_wait, or queries the "
                "deadline drain promises to serve get shed instead"
            )
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_wait = max_wait
        self.max_queue = None if max_queue is None else int(max_queue)
        self.shed_wait = shed_wait
        self._clock = clock or time.perf_counter
        self._pending: List[tuple] = []  # (query, t_submit, urgent)
        self._n_urgent = 0
        self.recorder = LatencyRecorder()
        self.n_batches = 0
        self.n_deadline_flushes = 0
        self.n_priority_flushes = 0
        self.n_shed_depth = 0
        self.n_shed_deadline = 0

    # ---------------- request path ----------------
    def submit(self, query: Query, *, urgent: bool = False) -> bool:
        """Queue one query. Returns False (and records a shed with
        reason ``"depth"``) when the bounded queue is full — the
        caller's signal to back off or retry elsewhere."""
        if self.max_queue is not None and len(self._pending) >= self.max_queue:
            self.n_shed_depth += 1
            self.recorder.record_shed("depth", cls=_slo_class(query))
            return False
        self._pending.append((query, self._clock(), bool(urgent)))
        if urgent:
            self._n_urgent += 1
        return True

    def submit_many(self, queries: Sequence[Query]) -> int:
        """Queue many; returns how many were admitted (the rest shed)."""
        t = self._clock()
        admitted = 0
        for q in queries:
            if (
                self.max_queue is not None
                and len(self._pending) >= self.max_queue
            ):
                self.n_shed_depth += 1
                self.recorder.record_shed("depth", cls=_slo_class(q))
                continue
            self._pending.append((q, t, False))
            admitted += 1
        return admitted

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ---------------- drain policies ----------------
    def _due(self, now: float) -> Optional[str]:
        """Why the front window should dispatch now (None: keep waiting)."""
        if not self._pending:
            return None
        if len(self._pending) >= self.max_batch:
            return "full"
        if self._n_urgent:
            return "urgent"
        if self.max_wait is not None and now - self._pending[0][1] >= self.max_wait:
            return "deadline"
        return None

    def _drain_window(self) -> List[QueryResult]:
        chunk = self._pending[: self.max_batch]
        t0 = self._clock()
        with obs_trace.span("scheduler_flush", cat="serving",
                            n=len(chunk)):
            results = self.engine.execute_batch([q for q, _, _ in chunk])
        t1 = self._clock()
        # dequeue only after success: an engine error must leave the
        # chunk queued (visible, retryable), not silently dropped
        del self._pending[: self.max_batch]
        self._n_urgent -= sum(1 for _, _, u in chunk if u)
        self.recorder.record_wall(t1 - t0)
        self.n_batches += 1
        for (q, t_sub, _), r in zip(chunk, results):
            r.latency_s = t1 - t_sub
            self.recorder.record(r.latency_s, cls=_slo_class(q))
        obs_trace.counter("queue_depth", len(self._pending))
        return results

    def flush(self) -> List[QueryResult]:
        """Drain the queue in ``max_batch`` windows; returns all results
        in submission order. When the engine is a pipelined SPMD engine
        (``engine.pipeline``), the host pack + collective launch of
        window k+1 overlaps window k's in-flight device intersect —
        ``end_batch`` is the only device sync (the trace's
        ``spmd_overlap_wait``). The control plane stays sequential
        host-side, so pipelined and unpipelined drains are bit-exact."""
        if getattr(self.engine, "pipeline", False):
            return self._flush_pipelined()
        out: List[QueryResult] = []
        while self._pending:
            out.extend(self._drain_window())
        return out

    # ---------------- pipelined drain ----------------
    def _begin_window(self) -> tuple:
        """Dispatch the front window without waiting on the device.
        The ``scheduler_flush`` span covers only the host-side begin —
        keeping spans disjoint per lane (the wait is its own span), so
        the exported trace stays well-nested under overlap."""
        chunk = self._pending[: self.max_batch]
        t0 = self._clock()
        with obs_trace.span("scheduler_flush", cat="serving",
                            n=len(chunk), pipelined=True):
            inflight = self.engine.begin_batch([q for q, _, _ in chunk])
        # the control plane (cache admission, serve matrix, the
        # measured-vs-modeled reconciliation) completed inside
        # begin_batch — the chunk is committed; only device counts
        # remain outstanding. A begin error leaves the chunk queued.
        del self._pending[: self.max_batch]
        self._n_urgent -= sum(1 for _, _, u in chunk if u)
        return chunk, inflight, t0

    def _finish_window(self, chunk, inflight, t0) -> List[QueryResult]:
        results = self.engine.end_batch(inflight)
        t1 = self._clock()
        self.recorder.record_wall(t1 - t0)
        self.n_batches += 1
        for (q, t_sub, _), r in zip(chunk, results):
            r.latency_s = t1 - t_sub
            self.recorder.record(r.latency_s, cls=_slo_class(q))
        obs_trace.counter("queue_depth", len(self._pending))
        return results

    def _flush_pipelined(self) -> List[QueryResult]:
        """Double-buffered drain: begin window k+1 before finishing
        window k, so at most one microbatch is in flight on device
        while the next one packs on host."""
        out: List[QueryResult] = []
        prev = None
        while self._pending or prev is not None:
            nxt = self._begin_window() if self._pending else None
            if prev is not None:
                out.extend(self._finish_window(*prev))
            prev = nxt
        return out

    def _shed_stale(self, now: float) -> None:
        """Drop pending queries that already waited past ``shed_wait``
        — serving them would return answers nobody is waiting for,
        while holding up the queries behind them."""
        if self.shed_wait is None or not self._pending:
            return
        keep: List[tuple] = []
        for item in self._pending:
            if now - item[1] >= self.shed_wait:
                self.n_shed_deadline += 1
                self.recorder.record_shed("deadline", cls=_slo_class(item[0]))
                if item[2]:
                    self._n_urgent -= 1
            else:
                keep.append(item)
        if len(keep) != len(self._pending):
            self._pending = keep

    def poll(self) -> List[QueryResult]:
        """Deadline-aware drain with load shedding: dispatch windows
        only while one is due (full / urgent pending / oldest past
        ``max_wait``); queries already stale past ``shed_wait`` are
        rejected-with-reason instead of served; otherwise return
        nothing and let requests keep coalescing."""
        out: List[QueryResult] = []
        while True:
            now = self._clock()
            self._shed_stale(now)
            reason = self._due(now)
            if reason is None:
                return out
            if reason == "deadline":
                self.n_deadline_flushes += 1
            elif reason == "urgent":
                self.n_priority_flushes += 1
            out.extend(self._drain_window())

    def run(self, queries: Sequence[Query]) -> List[QueryResult]:
        """Closed-loop convenience: submit all, drain to completion."""
        self.submit_many(queries)
        return self.flush()

    def latency_summary(self) -> LatencySummary:
        return self.recorder.summary()
