"""Microbatching scheduler: coalesce concurrent queries into one batch.

Point queries arrive one at a time but are cheapest answered together:
a batch shares row fetches (the provider is called once per distinct
vertex per batch), shares pair intersections (canonical dedup across
queries), and amortizes kernel/vectorization overhead over the whole
padded batch. The scheduler

- queues submitted queries with their arrival timestamp,
- drains them in windows of at most ``max_batch`` through
  ``QueryEngine.execute_batch``, and
- stamps each result with its submit-to-completion latency, feeding the
  p50/p99 ``LatencyRecorder``.

Two drain policies coexist:

- ``flush()`` — the closed-loop drain: empty the whole queue now
  (callers that own the loop, e.g. the launchers and benchmarks).
- ``poll()`` — deadline-aware batching for open-loop serving: a window
  dispatches when it is *full* (``max_batch``), when the **oldest
  pending query has waited ``max_wait`` seconds** (the latency deadline
  — without it a trickle of requests would wait forever for a full
  window), or when an **urgent** query is pending (priority flush:
  ``submit(q, urgent=True)`` dispatches the current window immediately,
  batching whatever happens to be queued in front of it). Otherwise
  ``poll`` returns nothing and requests keep coalescing.

``max_batch=1`` degenerates to one-query-at-a-time serving — the
baseline the serving benchmark compares against. The clock is
injectable so deadline behavior is testable without sleeping.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence

from .engine import QueryEngine
from .metrics import LatencyRecorder, LatencySummary
from .requests import Query, QueryResult

__all__ = ["MicrobatchScheduler"]


class MicrobatchScheduler:
    def __init__(
        self,
        engine: QueryEngine,
        *,
        max_batch: int = 64,
        max_wait: Optional[float] = None,
        clock: Optional[Callable[[], float]] = None,
    ):
        assert max_batch >= 1
        assert max_wait is None or max_wait >= 0.0
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_wait = max_wait
        self._clock = clock or time.perf_counter
        self._pending: List[tuple] = []  # (query, t_submit, urgent)
        self._n_urgent = 0
        self.recorder = LatencyRecorder()
        self.n_batches = 0
        self.n_deadline_flushes = 0
        self.n_priority_flushes = 0

    # ---------------- request path ----------------
    def submit(self, query: Query, *, urgent: bool = False) -> None:
        self._pending.append((query, self._clock(), bool(urgent)))
        if urgent:
            self._n_urgent += 1

    def submit_many(self, queries: Sequence[Query]) -> None:
        t = self._clock()
        self._pending.extend((q, t, False) for q in queries)

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ---------------- drain policies ----------------
    def _due(self, now: float) -> Optional[str]:
        """Why the front window should dispatch now (None: keep waiting)."""
        if not self._pending:
            return None
        if len(self._pending) >= self.max_batch:
            return "full"
        if self._n_urgent:
            return "urgent"
        if self.max_wait is not None and now - self._pending[0][1] >= self.max_wait:
            return "deadline"
        return None

    def _drain_window(self) -> List[QueryResult]:
        chunk = self._pending[: self.max_batch]
        t0 = self._clock()
        results = self.engine.execute_batch([q for q, _, _ in chunk])
        t1 = self._clock()
        # dequeue only after success: an engine error must leave the
        # chunk queued (visible, retryable), not silently dropped
        del self._pending[: self.max_batch]
        self._n_urgent -= sum(1 for _, _, u in chunk if u)
        self.recorder.record_wall(t1 - t0)
        self.n_batches += 1
        for (q, t_sub, _), r in zip(chunk, results):
            r.latency_s = t1 - t_sub
            self.recorder.record(r.latency_s)
        return results

    def flush(self) -> List[QueryResult]:
        """Drain the queue in ``max_batch`` windows; returns all results
        in submission order."""
        out: List[QueryResult] = []
        while self._pending:
            out.extend(self._drain_window())
        return out

    def poll(self) -> List[QueryResult]:
        """Deadline-aware drain: dispatch windows only while one is due
        (full / urgent pending / oldest past ``max_wait``); otherwise
        return nothing and let requests keep coalescing."""
        out: List[QueryResult] = []
        while True:
            reason = self._due(self._clock())
            if reason is None:
                return out
            if reason == "deadline":
                self.n_deadline_flushes += 1
            elif reason == "urgent":
                self.n_priority_flushes += 1
            out.extend(self._drain_window())

    def run(self, queries: Sequence[Query]) -> List[QueryResult]:
        """Closed-loop convenience: submit all, drain to completion."""
        self.submit_many(queries)
        return self.flush()

    def latency_summary(self) -> LatencySummary:
        return self.recorder.summary()
