"""Latency/throughput/shed accounting for the query service.

Percentiles use the 'lower' interpolation so a reported p99 is an
actually-observed latency, not an average of two observations.

Shed accounting backs the admission-control policy: a bounded queue
rejects work it cannot serve in time instead of letting every queued
query's latency collapse. ``shed_rate`` = shed / (served + shed) — the
fraction of offered load turned away, by reason.

Latencies can carry an optional class label (``cls``, e.g. the query
kind: ``"lcc"``/``"triangles"``/``"common_neighbors"``/``"top_k_lcc"``)
so per-SLO-class breakdowns are possible: ``summary_by_class()``
returns one ``LatencySummary`` per class (wall clock is shared across
classes, so per-class summaries report percentiles and shed counts but
no throughput), and the top-level summary carries ``shed_by_class`` /
``shed_rate_by_class``.

With an SLO policy active, each served latency can carry its class
deadline budget (``deadline_s``): ``slo_violations`` counts queries
served *late* (beyond budget — distinct from shed, which never served),
and ``slo_hit_rate`` = on-time / (served + shed): the fraction of
admitted-or-offered work that met its promise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

__all__ = ["LatencySummary", "LatencyRecorder"]


@dataclasses.dataclass
class LatencySummary:
    count: int
    wall_s: float
    throughput_qps: float
    p50_ms: float
    p90_ms: float
    p99_ms: float
    max_ms: float
    shed: int = 0
    shed_rate: float = 0.0
    shed_by_class: Dict[str, int] = dataclasses.field(default_factory=dict)
    shed_rate_by_class: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    slo_violations: int = 0
    slo_hit_rate: float = 1.0

    def as_dict(self) -> dict:
        out = {}
        for k, v in dataclasses.asdict(self).items():
            if isinstance(v, float):
                out[k] = round(v, 4)
            elif isinstance(v, dict):
                out[k] = {c: (round(x, 4) if isinstance(x, float) else x)
                          for c, x in sorted(v.items())}
            else:
                out[k] = v
        return out


def _summarize(lat: np.ndarray, wall_s: float, shed: int,
               shed_by_class: Optional[Dict[str, int]] = None,
               served_by_class: Optional[Dict[str, int]] = None,
               slo_violations: int = 0) -> LatencySummary:
    served = int(lat.size)
    rate = shed / (served + shed) if (served + shed) else 0.0
    shed_by_class = dict(shed_by_class or {})
    shed_rate_by_class = {}
    for c, n in shed_by_class.items():
        off = n + (served_by_class or {}).get(c, 0)
        shed_rate_by_class[c] = n / off if off else 0.0
    on_time = served - int(slo_violations)
    slo_hit = on_time / (served + shed) if (served + shed) else 1.0
    if served == 0:
        return LatencySummary(
            0, wall_s, 0.0, 0.0, 0.0, 0.0, 0.0, shed, rate,
            shed_by_class, shed_rate_by_class, int(slo_violations), slo_hit,
        )
    p50, p90, p99 = np.percentile(lat, [50, 90, 99], method="lower")
    return LatencySummary(
        count=served,
        wall_s=wall_s,
        # no measured wall => no throughput claim (a tiny guard
        # denominator would report ~1e12 qps instead of "unknown")
        throughput_qps=served / wall_s if wall_s > 0 else 0.0,
        p50_ms=float(p50) * 1e3,
        p90_ms=float(p90) * 1e3,
        p99_ms=float(p99) * 1e3,
        max_ms=float(lat.max()) * 1e3,
        shed=shed,
        shed_rate=rate,
        shed_by_class=shed_by_class,
        shed_rate_by_class=shed_rate_by_class,
        slo_violations=int(slo_violations),
        slo_hit_rate=slo_hit,
    )


class LatencyRecorder:
    def __init__(self):
        self._lat: List[float] = []
        self._cls_lat: Dict[str, List[float]] = {}
        self.wall_s = 0.0
        self.sheds: Dict[str, int] = {}  # reason -> queries rejected
        self._cls_sheds: Dict[str, int] = {}  # class -> queries rejected
        self.slo_violations = 0  # served late (beyond class budget)
        self._cls_violations: Dict[str, int] = {}

    def record(self, latency_s: float, cls: Optional[str] = None,
               deadline_s: Optional[float] = None) -> None:
        """One served latency. ``deadline_s`` is the query's SLO budget
        (submit-relative); a latency beyond it counts as a violation —
        served, but late."""
        self._lat.append(float(latency_s))
        if cls is not None:
            self._cls_lat.setdefault(str(cls), []).append(float(latency_s))
        if deadline_s is not None and latency_s > deadline_s:
            self.slo_violations += 1
            if cls is not None:
                c = str(cls)
                self._cls_violations[c] = self._cls_violations.get(c, 0) + 1

    def record_wall(self, seconds: float) -> None:
        self.wall_s += float(seconds)

    def record_shed(self, reason: str, n: int = 1,
                    cls: Optional[str] = None) -> None:
        self.sheds[reason] = self.sheds.get(reason, 0) + int(n)
        if cls is not None:
            cls = str(cls)
            self._cls_sheds[cls] = self._cls_sheds.get(cls, 0) + int(n)

    @property
    def count(self) -> int:
        return len(self._lat)

    @property
    def n_shed(self) -> int:
        return sum(self.sheds.values())

    def classes(self) -> List[str]:
        return sorted(set(self._cls_lat) | set(self._cls_sheds))

    def by_class(self) -> Dict[str, List[float]]:
        """Raw per-class latency observations (obs adapters read this)."""
        return {c: list(v) for c, v in self._cls_lat.items()}

    def summary(self) -> LatencySummary:
        lat = np.asarray(self._lat, np.float64)
        served_by_class = {c: len(v) for c, v in self._cls_lat.items()}
        return _summarize(lat, self.wall_s, self.n_shed,
                          shed_by_class=self._cls_sheds,
                          served_by_class=served_by_class,
                          slo_violations=self.slo_violations)

    def summary_by_class(self) -> Dict[str, LatencySummary]:
        """One summary per SLO class. wall_s/throughput are 0: the wall
        clock is shared across classes and not attributable to one."""
        return {
            c: _summarize(
                np.asarray(self._cls_lat.get(c, []), np.float64),
                0.0,
                self._cls_sheds.get(c, 0),
                slo_violations=self._cls_violations.get(c, 0),
            )
            for c in self.classes()
        }
