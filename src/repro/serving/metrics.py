"""Latency/throughput/shed accounting for the query service.

Percentiles use the 'lower' interpolation so a reported p99 is an
actually-observed latency, not an average of two observations.

Shed accounting backs the admission-control policy: a bounded queue
rejects work it cannot serve in time instead of letting every queued
query's latency collapse. ``shed_rate`` = shed / (served + shed) — the
fraction of offered load turned away, by reason.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

__all__ = ["LatencySummary", "LatencyRecorder"]


@dataclasses.dataclass
class LatencySummary:
    count: int
    wall_s: float
    throughput_qps: float
    p50_ms: float
    p90_ms: float
    p99_ms: float
    max_ms: float
    shed: int = 0
    shed_rate: float = 0.0

    def as_dict(self) -> dict:
        return {
            k: (round(v, 4) if isinstance(v, float) else v)
            for k, v in dataclasses.asdict(self).items()
        }


class LatencyRecorder:
    def __init__(self):
        self._lat: List[float] = []
        self.wall_s = 0.0
        self.sheds: Dict[str, int] = {}  # reason -> queries rejected

    def record(self, latency_s: float) -> None:
        self._lat.append(float(latency_s))

    def record_wall(self, seconds: float) -> None:
        self.wall_s += float(seconds)

    def record_shed(self, reason: str, n: int = 1) -> None:
        self.sheds[reason] = self.sheds.get(reason, 0) + int(n)

    @property
    def count(self) -> int:
        return len(self._lat)

    @property
    def n_shed(self) -> int:
        return sum(self.sheds.values())

    def summary(self) -> LatencySummary:
        lat = np.asarray(self._lat, np.float64)
        shed = self.n_shed
        rate = shed / (lat.size + shed) if (lat.size + shed) else 0.0
        if lat.size == 0:
            return LatencySummary(
                0, self.wall_s, 0.0, 0.0, 0.0, 0.0, 0.0, shed, rate
            )
        p50, p90, p99 = np.percentile(
            lat, [50, 90, 99], method="lower"
        )
        return LatencySummary(
            count=int(lat.size),
            wall_s=self.wall_s,
            throughput_qps=lat.size / max(self.wall_s, 1e-12),
            p50_ms=float(p50) * 1e3,
            p90_ms=float(p90) * 1e3,
            p99_ms=float(p99) * 1e3,
            max_ms=float(lat.max()) * 1e3,
            shed=shed,
            shed_rate=rate,
        )
