"""Closed-loop workload generators for the query service.

*Closed-loop* means the next request waits for the previous response:
these generators produce query batches the driver feeds through
``MicrobatchScheduler.run`` back-to-back, so measured latency is pure
service time — there is no arrival process and therefore no queueing
delay. To measure latency **under offered load** (arrivals that do not
wait for completions), pair the same query lists with
``repro.traffic``'s open-loop arrival processes and
``traffic.run_open_loop`` — for a fixed query multiset both paths
produce bit-identical answers, they differ only in *when* requests
enter the scheduler.

Three vertex-sampling regimes:

- ``uniform``  — every vertex equally likely (the paper's uniform
  control graphs: flat degree distribution ⇒ little reuse ⇒ caching
  must not help much, cf. Fig. 4),
- ``zipf``     — P(v) ∝ (deg(v)+1)^exponent, the hub-skewed regime a
  social-network point-query front end actually sees (Obs. 3.1/3.2:
  degree predicts reuse — the cache's best case),

and a read-write mix that interleaves query groups with edge-update
batches, driving the freshness/coherence path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence

import numpy as np

from ..streaming.updates import DELETE, INSERT, EdgeBatch
from .requests import Query, QueryKind

__all__ = [
    "sample_vertices",
    "make_queries",
    "ReadWriteEvent",
    "read_write_stream",
]

# default query mix: (lcc, triangles, common_neighbors, top_k_lcc)
DEFAULT_MIX = (0.45, 0.3, 0.2, 0.05)


def sample_vertices(
    degrees: np.ndarray,
    size: int,
    rng: np.random.Generator,
    *,
    kind: str = "zipf",
    exponent: float = 1.0,
) -> np.ndarray:
    """Sample query target vertices (uniform or degree/hub-skewed)."""
    n = degrees.shape[0]
    if kind == "uniform":
        return rng.integers(0, n, size=size)
    if kind == "zipf":
        w = (degrees.astype(np.float64) + 1.0) ** exponent
        return rng.choice(n, size=size, p=w / w.sum())
    raise ValueError(f"unknown workload kind: {kind}")


def make_queries(
    degrees: np.ndarray,
    n_queries: int,
    *,
    kind: str = "zipf",
    mix: Sequence[float] = DEFAULT_MIX,
    top_k: int = 8,
    exponent: float = 1.0,
    seed: int = 0,
) -> List[Query]:
    """Deterministic query workload over the current degree distribution."""
    rng = np.random.default_rng(seed)
    mix = np.asarray(mix, np.float64)
    kinds = rng.choice(4, size=n_queries, p=mix / mix.sum())
    vs = sample_vertices(
        degrees, 2 * n_queries, rng, kind=kind, exponent=exponent
    )
    out: List[Query] = []
    for i, kq in enumerate(kinds):
        u, v = int(vs[2 * i]), int(vs[2 * i + 1])
        if kq == QueryKind.LCC:
            out.append(Query.lcc(u))
        elif kq == QueryKind.TRIANGLES:
            out.append(Query.triangles(u))
        elif kq == QueryKind.COMMON_NEIGHBORS:
            out.append(Query.common_neighbors(u, v if v != u else (u + 1) % degrees.shape[0]))
        else:
            out.append(Query.top_k_lcc(top_k))
    return out


@dataclasses.dataclass
class ReadWriteEvent:
    """One step of a read-write mixed stream: exactly one of the two."""

    queries: Optional[List[Query]] = None
    update: Optional[EdgeBatch] = None

    @property
    def is_update(self) -> bool:
        return self.update is not None


def read_write_stream(
    degrees_fn,
    n: int,
    n_events: int,
    *,
    write_frac: float = 0.2,
    queries_per_event: int = 32,
    updates_per_event: int = 64,
    delete_frac: float = 0.3,
    kind: str = "zipf",
    seed: int = 0,
) -> Iterator[ReadWriteEvent]:
    """Closed-loop read-write mix. ``degrees_fn()`` returns the *current*
    degree array so query skew tracks the live graph as writes land."""
    rng = np.random.default_rng(seed)
    for i in range(n_events):
        if rng.random() < write_frac:
            e = rng.integers(0, n, size=(updates_per_event, 2))
            op = np.where(
                rng.random(updates_per_event) < delete_frac, DELETE, INSERT
            ).astype(np.int8)
            yield ReadWriteEvent(update=EdgeBatch(u=e[:, 0], v=e[:, 1], op=op))
        else:
            yield ReadWriteEvent(
                queries=make_queries(
                    degrees_fn(),
                    queries_per_event,
                    kind=kind,
                    seed=seed + 1000 + i,
                )
            )
