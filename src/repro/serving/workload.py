"""Alias of :mod:`repro.serving.closed_loop` (its historical name).

The generators here are *closed-loop* (next request waits for the
previous response); the module was renamed to say so once the
open-loop traffic plane (``repro.traffic``) landed. Existing imports
keep working through this re-export — no deprecation shims, both
names are first-class.
"""
from .closed_loop import (  # noqa: F401
    DEFAULT_MIX,
    ReadWriteEvent,
    make_queries,
    read_write_stream,
    sample_vertices,
)

__all__ = [
    "DEFAULT_MIX",
    "sample_vertices",
    "make_queries",
    "ReadWriteEvent",
    "read_write_stream",
]
