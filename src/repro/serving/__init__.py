"""Online graph query serving over the 1D-partitioned live graph.

Turns the batch-epoch reproduction into a request-driven service:

- ``requests``  — ``Query``/``QueryResult`` types (lcc, triangles,
                  common_neighbors, top_k_lcc)
- ``provider``  — row read path: rank views over the shared
                  ``core.runtime.ShardedRuntime`` (``DirectRowProvider``
                  uncached, ``CacheBackedRowProvider`` degree-scored
                  ClampiCache carrying real payloads, runtime-fanout
                  coherence)
- ``engine``    — ``QueryEngine``: batched point-query execution with
                  batch-wide row-fetch + pair dedup over the Pallas
                  intersect kernels; ``ShardedQueryEngine``: p engines
                  routing each query to its owner rank
- ``scheduler`` — ``MicrobatchScheduler``: request coalescing with FIFO
                  + deadline (``max_wait``) + priority (urgent) drains,
                  per-class SLO deadlines with EDF window selection,
                  tenant-quota admission, p50/p99 latency accounting
- ``closed_loop`` — uniform / Zipf(hub-skewed) / read-write generators
                  (closed-loop: next request waits for the previous
                  response; ``workload`` is its historical alias). The
                  open-loop arrival side lives in ``repro.traffic``.
- ``service``   — ``LiveQueryService``: queries + streaming updates over
                  one shared store/runtime with a verified staleness
                  bound (single-rank or cross-rank), plus the traffic
                  plane hooks (SLO policy, tenant quotas, workload
                  scorer, injectable clock)
"""
from .requests import Query, QueryKind, QueryResult  # noqa: F401
from .provider import (  # noqa: F401
    CacheBackedRowProvider,
    DirectRowProvider,
    ProviderCoherenceHook,
    ProviderStats,
    RuntimeRowProvider,
)
from .engine import QueryEngine, ShardedQueryEngine  # noqa: F401
from .scheduler import MicrobatchScheduler  # noqa: F401
from .metrics import LatencyRecorder, LatencySummary  # noqa: F401
from .closed_loop import (  # noqa: F401
    ReadWriteEvent,
    make_queries,
    read_write_stream,
    sample_vertices,
)
from .service import LiveQueryService  # noqa: F401
