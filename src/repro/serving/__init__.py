"""Online graph query serving over the 1D-partitioned live graph.

Turns the batch-epoch reproduction into a request-driven service:

- ``requests``  — ``Query``/``QueryResult`` types (lcc, triangles,
                  common_neighbors, top_k_lcc)
- ``provider``  — row read path: ``DirectRowProvider`` (uncached) and
                  ``CacheBackedRowProvider`` (degree-scored ClampiCache
                  carrying real row payloads, coherence-invalidated)
- ``engine``    — ``QueryEngine``: batched point-query execution with
                  batch-wide row-fetch + pair dedup over the Pallas
                  intersect kernels
- ``scheduler`` — ``MicrobatchScheduler``: request coalescing + p50/p99
                  latency accounting
- ``workload``  — uniform / Zipf(hub-skewed) / read-write generators
- ``service``   — ``LiveQueryService``: queries + streaming updates over
                  one shared store with a verified staleness bound
"""
from .requests import Query, QueryKind, QueryResult  # noqa: F401
from .provider import (  # noqa: F401
    CacheBackedRowProvider,
    DirectRowProvider,
    ProviderCoherenceHook,
    ProviderStats,
)
from .engine import QueryEngine  # noqa: F401
from .scheduler import MicrobatchScheduler  # noqa: F401
from .metrics import LatencyRecorder, LatencySummary  # noqa: F401
from .workload import (  # noqa: F401
    ReadWriteEvent,
    make_queries,
    read_write_stream,
    sample_vertices,
)
from .service import LiveQueryService  # noqa: F401
