"""Query engine: answers point/batch graph queries without a full epoch.

Execution of one microbatch (the scheduler's unit of work):

1. **Endpoint fetch** — the distinct endpoints of all queries in the
   batch are fetched through the row provider once (order of first use,
   the same within-round dedup ``rma.build_sharded_problem`` applies).
2. **Neighbor fetch** — triangle/LCC queries need the rows of every
   neighbor of the target; the union over the batch is deduplicated
   against the endpoint set and fetched in one provider call. On a
   hub-skewed workload most of these rows repeat across queries — the
   reuse the degree-scored cache converts into hits.
3. **Pair intersection** — every (target, neighbor) and (u, v) pair is
   canonicalized (min, max) and deduplicated across the whole batch,
   then counted in one width-bucketed ``batched_pair_counts`` call
   (Pallas ``intersect_count`` kernel on TPU, vectorized host binary
   search elsewhere).
4. **Scatter** — per-vertex sums give ``T(v) = S(v)/2`` and
   ``LCC(v) = 2 T(v) / (deg (deg-1))`` with arithmetic identical to
   ``core.triangles`` (bit-exact against the batch oracle, using the
   *provider's* row widths as degrees so answers are consistent with the
   rows actually read).

``top_k_lcc`` reads the exact LCC array from ``lcc_source`` (the
streaming engine's incrementally-maintained scores); ties break by
vertex id, matching the reference ordering ``sort by (-lcc, id)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.runtime import FetchEvent, ShardedRuntime
from ..core.triangles import lcc_scores, triangles_per_vertex
from ..obs import trace as obs_trace
from ..kernels.bucketing import pack_rows, width_classes
from ..kernels.delta_intersect import delta_intersect_masks
from ..kernels.point_query import batched_pair_counts
from ..kernels.resident_intersect import resident_intersect_counts
from .provider import DirectRowProvider, RuntimeRowProvider
from .requests import Query, QueryKind, QueryResult

__all__ = [
    "PreparedBatch",
    "InflightBatch",
    "QueryEngine",
    "ShardedQueryEngine",
]


@dataclasses.dataclass
class PreparedBatch:
    """Host-side half of one microbatch: rows fetched (control plane
    complete — cache stats and the serve matrix are already charged),
    pair worklist deduplicated. What remains is counting the unique
    pairs — in loop mode immediately on this engine, in SPMD mode as
    one rank-sharded device call across all engines."""

    queries: Sequence[Query]
    tri: List[Query]
    cn: List[Query]
    rows: Dict[int, np.ndarray]
    u_lo: np.ndarray  # unique canonical pairs, low id
    u_hi: np.ndarray
    inv: np.ndarray  # raw pair -> unique pair scatter
    qid: Optional[np.ndarray]  # tri-query index per raw tri pair
    n_tri_pairs: int  # raw tri pairs (rest of `inv` are cn pairs)
    record: Optional[List[FetchEvent]] = None


class QueryEngine:
    def __init__(
        self,
        store,
        provider=None,
        *,
        use_kernel: Optional[bool] = None,
        block_e: int = 128,
        interpret: Optional[bool] = None,
        lcc_source: Optional[Callable[[], np.ndarray]] = None,
    ):
        self.store = store  # DynamicCSR or CSRGraph (row/degrees/n)
        self.provider = provider or DirectRowProvider(store)
        if use_kernel is None:
            import jax

            use_kernel = jax.default_backend() == "tpu"
        self.use_kernel = use_kernel
        self.block_e = block_e
        self.interpret = interpret
        self.lcc_source = lcc_source
        self._static_lcc: Optional[np.ndarray] = None  # lazy, static graphs
        self._static_lcc_token = None  # store state the cached array is for
        self.n_queries = 0
        self.n_pairs_total = 0  # row pairs after batch-wide dedup
        self.n_pairs_raw = 0  # row pairs before dedup
        self.n_pairs_resident = 0  # pairs served via the device tier
        self.host_pack_bytes = 0  # row bytes packed host-side per call

    # ---------------- point/batch execution ----------------
    def execute_batch(self, queries: Sequence[Query]) -> List[QueryResult]:
        prep = self.prepare_batch(queries)
        rank = int(getattr(self.provider, "rank", -1))
        with obs_trace.span("intersect_kernel", rank=rank, cat="serving",
                            pairs=prep.u_lo.size):
            counts = self._pair_counts(prep.u_lo, prep.u_hi, prep.rows)
        return self.finalize_batch(prep, counts)

    def prepare_batch(
        self,
        queries: Sequence[Query],
        record: Optional[List[FetchEvent]] = None,
    ) -> PreparedBatch:
        """Fetch rows + build the deduplicated pair worklist (all the
        control-plane work of a microbatch; see ``PreparedBatch``)."""
        tri = [q for q in queries
               if q.kind in (QueryKind.LCC, QueryKind.TRIANGLES)]
        cn = [q for q in queries if q.kind == QueryKind.COMMON_NEIGHBORS]
        rows = self._fetch_rows_for(tri, cn, record=record)

        # pair worklist: (target, neighbor) per tri/lcc query + (u, v) per
        # common-neighbors query, all as flat arrays
        a_parts: List[np.ndarray] = []
        b_parts: List[np.ndarray] = []
        qid_parts: List[np.ndarray] = []  # tri-query index per pair
        for i, q in enumerate(tri):
            r = rows[q.u]
            if r.size:
                a_parts.append(np.full(r.size, q.u, np.int64))
                b_parts.append(r.astype(np.int64))
                qid_parts.append(np.full(r.size, i, np.int64))
        if cn:
            a_parts.append(np.array([q.u for q in cn], np.int64))
            b_parts.append(np.array([q.v for q in cn], np.int64))
        a = np.concatenate(a_parts) if a_parts else np.zeros(0, np.int64)
        b = np.concatenate(b_parts) if b_parts else np.zeros(0, np.int64)

        # batch-wide canonical dedup: each distinct unordered pair is
        # intersected exactly once, results scattered back via inverse
        key = np.minimum(a, b) * np.int64(self.store.n) + np.maximum(a, b)
        uniq, inv = np.unique(key, return_inverse=True)
        u_lo = uniq // self.store.n
        u_hi = uniq % self.store.n
        self.n_pairs_total += int(uniq.size)
        self.n_pairs_raw += int(key.size)
        qid = np.concatenate(qid_parts) if qid_parts else None
        return PreparedBatch(
            queries=queries,
            tri=tri,
            cn=cn,
            rows=rows,
            u_lo=u_lo,
            u_hi=u_hi,
            inv=inv,
            qid=qid,
            n_tri_pairs=int(key.size - len(cn)),
            record=record,
        )

    def finalize_batch(
        self, prep: PreparedBatch, uniq_counts: np.ndarray
    ) -> List[QueryResult]:
        """Scatter unique-pair counts back into query results (the
        execution-mode-independent half: loop and SPMD counts are the
        same integers, so results are bit-identical)."""
        queries, tri, cn, rows = prep.queries, prep.tri, prep.cn, prep.rows
        counts = np.asarray(uniq_counts, np.int64)[prep.inv]

        # scatter: S(v) = sum_j |N(v) ∩ N(j)| per tri query, T = S/2.
        # S is even whenever the row views are mutually consistent; a
        # stale provider (no coherence hook) can make membership
        # asymmetric and S odd — serve floor(S/2) rather than killing
        # the whole microbatch (staleness is the documented divergence
        # mode, and audit_freshness/verify expose it).
        n_tri_pairs = prep.n_tri_pairs
        s = np.zeros(len(tri), np.int64)
        if n_tri_pairs:
            np.add.at(s, prep.qid, counts[:n_tri_pairs])
        t_of = s // 2
        cn_counts = counts[n_tri_pairs:]

        out: List[QueryResult] = []
        i_tri = 0
        i_cn = 0
        for q in queries:
            if q.kind == QueryKind.TOP_K_LCC:
                out.append(self._top_k(q))
            elif q.kind == QueryKind.COMMON_NEIGHBORS:
                c = int(cn_counts[i_cn])
                i_cn += 1
                ids = np.intersect1d(rows[q.u], rows[q.v])
                assert ids.size == c, "kernel count disagrees with ids"
                out.append(QueryResult(q, value=c, ids=ids))
            else:
                t = int(t_of[i_tri])
                d = float(rows[q.u].size)
                i_tri += 1
                if q.kind == QueryKind.TRIANGLES:
                    out.append(QueryResult(q, value=t))
                else:
                    denom = d * (d - 1.0)
                    lcc = 2.0 * t / denom if denom > 0 else 0.0
                    out.append(QueryResult(q, value=lcc))
        self.n_queries += len(queries)
        return out

    # ---------------- internals ----------------
    @property
    def residency(self):
        """Device-resident tier behind this engine's provider (or None)."""
        return getattr(self.provider, "residency", None)

    def _fetch_rows_for(
        self,
        tri: Sequence[Query],
        cn: Sequence[Query],
        record: Optional[List[FetchEvent]] = None,
    ) -> Dict[int, np.ndarray]:
        """Two-phase dedup'd row fetch: endpoints, then their neighbors.

        Neighbors resident in the device tier are NOT fetched: their
        rows stay on device and the pair intersection gathers them from
        the residency buffer — the host-row-materialization saving the
        tier exists for. (Endpoints are always fetched: the engine
        needs their rows to enumerate pairs and for degrees/ids.)

        Tenant-tagged queries build a vertex -> tenant map with
        first-requester semantics (a row two tenants' queries share is
        charged to whichever query claims it first, matching the
        cache's first-fetcher entry tag); neighbor fetches inherit the
        tenant of the query whose row surfaced them."""
        endpoints = [q.u for q in tri]
        for q in cn:
            endpoints.extend((q.u, q.v))
        tenants: Optional[Dict[int, str]] = None
        if any(q.tenant for q in tri) or any(q.tenant for q in cn):
            tenants = {}
            for q in tri:
                tenants.setdefault(int(q.u), q.tenant)
            for q in cn:
                tenants.setdefault(int(q.u), q.tenant)
                tenants.setdefault(int(q.v), q.tenant)
        ep = np.array(endpoints, np.int64)
        # dedup preserving order of first use (what the cache replay sees)
        _, first = np.unique(ep, return_index=True)
        need = ep[np.sort(first)]
        rows = self.provider.fetch_rows(need, record=record,
                                        tenants=tenants)
        if tri:
            cat = np.concatenate(
                [rows[q.u] for q in tri]
            ).astype(np.int64)
            nbrs, first_nbr = np.unique(cat, return_index=True)
            if tenants is not None and cat.size:
                qidx = np.concatenate(
                    [np.full(rows[q.u].size, i, np.int64)
                     for i, q in enumerate(tri)]
                )
                owner_q = qidx[first_nbr]
                for v, qi in zip(nbrs.tolist(), owner_q.tolist()):
                    tenants.setdefault(int(v), tri[qi].tenant)
            need2 = nbrs[~np.isin(nbrs, need, assume_unique=False)]
            dev = self.residency
            if dev is not None and need2.size:
                need2 = need2[dev.slot_of(need2) < 0]
            if need2.size:
                rows.update(self.provider.fetch_rows(need2, record=record,
                                                     tenants=tenants))
        return rows

    def _pair_counts(
        self, u_lo: np.ndarray, u_hi: np.ndarray, rows: Dict[int, np.ndarray]
    ) -> np.ndarray:
        """Counts per unique pair, routed by residency: a pair whose
        row was left on device (not in ``rows``) goes through the
        ``resident_intersect`` gather; fully-materialized pairs take
        the classic width-bucketed host path."""
        sent = self.store.n
        dev = self.residency
        if dev is None:
            out = batched_pair_counts(
                [rows[int(x)] for x in u_lo],
                [rows[int(x)] for x in u_hi],
                sentinel=sent,
                use_kernel=self.use_kernel,
                block_e=self.block_e,
                interpret=self.interpret,
            )
            self.host_pack_bytes += 4 * int(
                sum(rows[int(x)].size for x in u_lo)
                + sum(rows[int(x)].size for x in u_hi)
            )
            return out
        lo_in, hi_in, groups = self._residency_groups(u_lo, u_hi, rows)
        out = np.zeros(u_lo.size, np.int64)
        host = lo_in & hi_in
        if host.any():
            idx = np.flatnonzero(host)
            ra = [rows[int(u_lo[i])] for i in idx]
            rb = [rows[int(u_hi[i])] for i in idx]
            out[idx] = batched_pair_counts(
                ra, rb, sentinel=sent, use_kernel=self.use_kernel,
                block_e=self.block_e, interpret=self.interpret,
            )
            self.host_pack_bytes += 4 * int(
                sum(r.size for r in ra) + sum(r.size for r in rb)
            )
        for res_idx, res_v, mat_v in groups:
            if res_idx.size == 0:
                continue
            out[res_idx] = self._resident_counts(
                dev,
                res_v[res_idx],
                [rows[int(x)] for x in mat_v[res_idx]],
                sentinel=sent,
            )
            self.n_pairs_resident += int(res_idx.size)
        return out

    @staticmethod
    def _residency_groups(
        u_lo: np.ndarray, u_hi: np.ndarray, rows: Dict[int, np.ndarray]
    ):
        """Residency routing shared by loop mode (``_pair_counts``) and
        SPMD mode (``ShardedQueryEngine._shard_work``): which side of
        each unique pair was materialized, plus the routed groups in the
        canonical order (resident-hi first, then resident-lo). ~hi_in
        and ~lo_in are disjoint (asserted): exactly one side of a
        routed pair stayed on device."""
        n_pairs = u_lo.size
        lo_in = np.fromiter((int(x) in rows for x in u_lo), bool, n_pairs)
        hi_in = np.fromiter((int(x) in rows for x in u_hi), bool, n_pairs)
        assert bool(np.all(lo_in | hi_in)), (
            "every pair has at least one fetched endpoint"
        )
        groups = (
            (np.flatnonzero(~hi_in), u_hi, u_lo),
            (np.flatnonzero(~lo_in), u_lo, u_hi),
        )
        return lo_in, hi_in, groups

    @staticmethod
    def _claim_resident(dev, vs: np.ndarray) -> np.ndarray:
        """Claim + epoch-check one routed group's resident side (the
        ledger update both execution modes must perform identically);
        returns the slots."""
        slots, epochs = dev.claim(vs)
        assert bool(np.all(slots >= 0)), "routing bug: non-resident pair"
        dev.check(slots, epochs)  # stale handles are impossible by design
        return slots

    def _resident_counts(
        self,
        dev,
        resident_v: np.ndarray,
        rows_other: List[np.ndarray],
        *,
        sentinel: int,
    ) -> np.ndarray:
        """|row(resident_v[i]) ∩ rows_other[i]| with the resident side
        gathered from the device buffer (kernel path) or its host
        mirror (host path) — never re-materialized from the store."""
        slots = self._claim_resident(dev, resident_v)
        out = np.zeros(len(rows_other), np.int64)
        self.host_pack_bytes += 4 * int(sum(r.size for r in rows_other))
        widths = width_classes([r.size for r in rows_other])
        for w in np.unique(widths):
            idx = np.flatnonzero(widths == w)
            packed = pack_rows([rows_other[i] for i in idx], int(w), sentinel)
            if self.use_kernel:
                out[idx] = resident_intersect_counts(
                    dev.rows, slots[idx], packed,
                    sentinel=sentinel, interpret=self.interpret,
                )
            else:
                out[idx] = delta_intersect_masks(
                    packed, dev.host_rows(slots[idx]), sentinel=sentinel
                ).sum(1)
        return out

    def _top_k(self, q: Query) -> QueryResult:
        lcc = self._current_lcc()
        k = min(q.k, lcc.shape[0])
        # reference ordering: sort by (-lcc, vertex id), take first k
        order = np.lexsort((np.arange(lcc.shape[0]), -lcc))[:k]
        return QueryResult(
            q,
            value=float(lcc[order[0]]) if k else 0.0,
            ids=order.astype(np.int64),
            values=lcc[order],
        )

    def _current_lcc(self) -> np.ndarray:
        if self.lcc_source is not None:
            return self.lcc_source()
        # no incremental source: recount lazily, caching per store state —
        # a mutated DynamicCSR must not serve a pre-mutation ranking
        token = getattr(self.store, "n_mutations", None)
        if self._static_lcc is None or token != self._static_lcc_token:
            csr = (
                self.store.to_csr()
                if hasattr(self.store, "to_csr")
                else self.store
            )
            self._static_lcc = lcc_scores(csr, triangles_per_vertex(csr))
            self._static_lcc_token = token
        return self._static_lcc


@dataclasses.dataclass
class InflightBatch:
    """One dispatched-but-unfinalized SPMD microbatch. The control
    plane (cache admission, stats, serve matrix, the measured-vs-
    modeled reconciliation) completed at ``begin_batch``; only the
    device counts are outstanding — ``end_batch`` waits and scatters
    them into results."""

    queries: Sequence[Query]
    by_rank: Dict[int, List[int]]
    preps: List[Optional[PreparedBatch]]
    pending: object  # distributed.spmd_runtime.PendingUnit


class ShardedQueryEngine:
    """p per-rank ``QueryEngine`` instances over one shared runtime.

    Each microbatch is split by *owner rank* — ``lcc(v)``/``triangles(v)``
    execute where ``v`` lives, ``common_neighbors(u, v)`` where ``u``
    lives, ``top_k_lcc`` at rank 0 (it reads the replicated incremental
    LCC array) — and each rank's sub-batch runs through that rank's
    engine and provider view, so remote rows pass through that rank's
    cache exactly as the static engine's all-to-all serve lists would
    ship them. Results reassemble in submission order, so answers are
    independent of the routing (the scheduler and callers can't tell p=1
    from p=8 apart from the metrics).

    ``execution`` picks how the p rank views run their intersect work:

    - ``"loop"`` — sequential Python loop over the p in-process engines
      (the modeled runtime, as before);
    - ``"spmd"`` — one rank-sharded ``shard_map`` call per microbatch
      over a p-device mesh (``SpmdIntersectExecutor``): every rank's
      held rows are device-resident, remote misses arrive through a
      single ``all_to_all`` whose measured traffic is asserted equal to
      the ``serve_rows`` delta the control plane modeled, and pair
      counts run on device. Answers, per-rank cache stats, and the
      serve matrix are bit-identical between the two modes (only the
      host-packing ledgers differ — SPMD does not pack rows per pair).

    ``pipeline`` (SPMD only) exposes the double-buffered shape: a
    microbatch splits into ``begin_batch`` (prepare + dispatch, no
    device sync) and ``end_batch`` (wait + finalize), so a caller — the
    ``MicrobatchScheduler``'s ``flush`` — can overlap the pack +
    collective of window k+1 with the in-flight intersect of window k.
    Pipelined and unpipelined execution are bit-identical: the control
    plane is sequential host-side either way."""

    def __init__(
        self,
        store,
        runtime: ShardedRuntime,
        *,
        use_kernel: Optional[bool] = None,
        block_e: int = 128,
        interpret: Optional[bool] = None,
        lcc_source: Optional[Callable[[], np.ndarray]] = None,
        execution: str = "loop",
        pipeline: bool = False,
    ):
        assert execution in ("loop", "spmd"), execution
        assert not (pipeline and execution != "spmd"), (
            "pipeline requires execution='spmd'"
        )
        self.runtime = runtime
        self.pipeline = bool(pipeline)
        self.engines = [
            QueryEngine(
                store,
                RuntimeRowProvider(runtime, rank),
                use_kernel=use_kernel,
                block_e=block_e,
                interpret=interpret,
                lcc_source=lcc_source,
            )
            for rank in range(runtime.p)
        ]
        self.store = store
        self.execution = execution
        self.spmd = None
        if execution == "spmd":
            from ..distributed.spmd_runtime import SpmdIntersectExecutor

            self.spmd = SpmdIntersectExecutor(
                runtime.part,
                runtime.n,
                use_kernel=use_kernel,
                block_e=block_e,
                interpret=interpret,
                runtime=runtime,
            )

    def route(self, q: Query) -> int:
        """Executing rank for ``q`` — the partition's ``route()``, which
        is the owner except for split hub vertices, whose queries spread
        round-robin across ranks (any rank can read any row through the
        transport, so routing moves load, never answers)."""
        if q.kind == QueryKind.TOP_K_LCC:
            return 0
        return int(self.runtime.part.route(q.u))

    def execute_batch(self, queries: Sequence[Query]) -> List[QueryResult]:
        by_rank: Dict[int, List[int]] = {}
        for i, q in enumerate(queries):
            by_rank.setdefault(self.route(q), []).append(i)
        if self.execution == "spmd":
            return self.end_batch(self.begin_batch(queries, by_rank))
        out: List[Optional[QueryResult]] = [None] * len(queries)
        for rank, idxs in sorted(by_rank.items()):
            results = self.engines[rank].execute_batch(
                [queries[i] for i in idxs]
            )
            for i, r in zip(idxs, results):
                out[i] = r
        return out  # type: ignore[return-value]

    # ---------------- SPMD execution ----------------
    def begin_batch(
        self,
        queries: Sequence[Query],
        by_rank: Optional[Dict[int, List[int]]] = None,
    ) -> InflightBatch:
        """Dispatch one device-parallel microbatch WITHOUT waiting on
        the device: per-rank prepare (control plane: cache admission,
        stats, serve matrix — host-side and identical to loop mode),
        then ONE rank-sharded intersect launch. The measured collective
        rows are asserted equal, owner-for-requester, to the modeled
        ``serve_rows`` delta this same microbatch produced — the full
        ledger exists at dispatch, so reconciliation does not need the
        counts. A pipelined caller may ``begin_batch`` the next
        microbatch before ``end_batch``-ing this one."""
        from ..distributed.spmd_runtime import ShardWork

        if by_rank is None:
            by_rank = {}
            for i, q in enumerate(queries):
                by_rank.setdefault(self.route(q), []).append(i)
        rt = self.runtime
        serve_before = rt.serve_rows.copy()
        empty = np.zeros(0, np.int64)
        preps: List[Optional[PreparedBatch]] = [None] * rt.p
        shards: List[ShardWork] = []
        for rank in range(rt.p):
            idxs = by_rank.get(rank)
            if not idxs:
                shards.append(ShardWork(rank, empty, empty, {}))
                continue
            record: List[FetchEvent] = []
            prep = self.engines[rank].prepare_batch(
                [queries[i] for i in idxs], record=record
            )
            preps[rank] = prep
            shards.append(self._shard_work(rank, prep, record))
        pending = self.spmd.dispatch(shards, rt.store)
        measured = pending.unit.rows_shipped
        modeled = rt.serve_rows - serve_before
        assert np.array_equal(measured, modeled), (
            "SPMD collective traffic diverged from the modeled serve "
            f"matrix:\nmeasured=\n{measured}\nmodeled=\n{modeled}"
        )
        return InflightBatch(queries, by_rank, preps, pending)

    def end_batch(self, inflight: InflightBatch) -> List[QueryResult]:
        """Reconciliation barrier: wait for the in-flight microbatch's
        device counts, then per-rank finalize and reassemble results in
        submission order."""
        counts, _unit = inflight.pending.wait()
        out: List[Optional[QueryResult]] = [None] * len(inflight.queries)
        for rank, idxs in sorted(inflight.by_rank.items()):
            results = self.engines[rank].finalize_batch(
                inflight.preps[rank], counts[rank]
            )
            for i, r in zip(idxs, results):
                out[i] = r
        return out  # type: ignore[return-value]

    def _shard_work(
        self, rank: int, prep: PreparedBatch, record: List[FetchEvent]
    ):
        """Turn one rank's prepared microbatch into its SPMD slice:
        local rows / cache hits / device-mirror rows stay rank-resident,
        misses ship through the collective. Device-tier bookkeeping
        (claim + epoch check per resident pair side) runs exactly as
        loop mode's resident routing would, so the residency ledgers
        stay field-for-field identical."""
        from ..distributed.spmd_runtime import ShardWork

        eng = self.engines[rank]
        rows = prep.rows
        held: Dict[int, np.ndarray] = {}
        fetched: List[int] = []
        for ev in record:
            if ev.kind == "miss":
                fetched.append(ev.v)
            else:
                held[ev.v] = rows[ev.v]
        dev = eng.residency
        u_lo, u_hi = prep.u_lo, prep.u_hi
        if dev is not None and u_lo.size:
            # the same routing (and group order) loop-mode _pair_counts
            # applies, so the residency claim/check ledgers match.
            _, _, groups = QueryEngine._residency_groups(u_lo, u_hi, rows)
            for res_idx, res_v, _mat_v in groups:
                if res_idx.size == 0:
                    continue
                vs = res_v[res_idx]
                slots = QueryEngine._claim_resident(dev, vs)
                mirror = dev.host_rows(slots)
                widths = dev.widths[slots]
                for i, v in enumerate(vs):
                    v = int(v)
                    if v not in held:
                        held[v] = mirror[i, : int(widths[i])].copy()
                eng.n_pairs_resident += int(res_idx.size)
        return ShardWork(
            rank,
            prep.u_lo.astype(np.int64),
            prep.u_hi.astype(np.int64),
            held,
            fetched,
        )

    # ---------------- aggregated accounting ----------------
    @property
    def n_queries(self) -> int:
        return sum(e.n_queries for e in self.engines)

    @property
    def n_pairs_total(self) -> int:
        return sum(e.n_pairs_total for e in self.engines)

    @property
    def n_pairs_raw(self) -> int:
        return sum(e.n_pairs_raw for e in self.engines)

    @property
    def n_pairs_resident(self) -> int:
        return sum(e.n_pairs_resident for e in self.engines)

    @property
    def host_pack_bytes(self) -> int:
        return sum(e.host_pack_bytes for e in self.engines)
