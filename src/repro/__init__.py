"""repro: Asynchronous distributed-memory TC/LCC with RMA caching, on JAX.

Framework layout:
  core/         the paper's algorithms (CSR, 1D partition, intersection,
                RMA pull schedule, CLaMPI cache, async engine, TriC baseline)
  graphs/       graph data pipeline (R-MAT, power-law stand-ins, sampler)
  models/       assigned architectures (LM transformers, GNNs, recsys)
  streaming/    incremental TC/LCC under batched edge updates (DynamicCSR
                delta store, exact delta engine, cache coherence)
  data/         token/recsys synthetic pipelines
  train/serve/  training and serving substrates
  distributed/  sharding rules, fault tolerance, hub-replication gather
  kernels/      Pallas TPU kernels (+ jnp oracles)
  configs/      one config per assigned architecture
  launch/       mesh, dry-run, train/serve entry points
"""

__version__ = "1.0.0"
