"""One labeled metric registry over the stack's scattered ledgers.

The repo accumulated five ad-hoc accounting dataclasses — per-rank
``ProviderStats`` (host transport), ``CacheStats`` (CLaMPI layer),
``ResidencyStats`` (device tier), ``CollectiveLedger`` (measured SPMD
wire traffic), and the serving ``LatencyRecorder`` — each with its own
report printer and none queryable together. This module gives them a
single address space: every number becomes a counter, gauge, or
histogram keyed by ``(name, rank, tier, phase)``:

- ``rank``  — which of the p ranks (-1 = global / cross-rank)
- ``tier``  — where the number lives: ``host`` (provider transport),
  ``host_cache`` (CLaMPI), ``device`` (resident tier), ``wire``
  (modeled or measured communication), ``serving`` (latency/shed)
- ``phase`` — the span-taxonomy phase it attributes to (see
  ``trace.PHASES``), empty when not phase-specific

Adapters (``record_*``) translate the existing dataclasses verbatim —
they never mutate the sources, so calling them twice on fresh
registries is idempotent per snapshot. ``fold_trace`` adds the time
dimension (per-phase wall seconds/calls/bytes from a ``Tracer``), and
``record_reconciliation`` promotes the measured-vs-modeled RMA byte
comparison (``CollectiveLedger`` vs. the runtime's serve matrix) to
first-class counters plus an agreement gauge — the invariant CI
validates on every smoke.

Derived placement gauges shipped here because ROADMAP items 1/2 need
them measurable: ``load_imbalance`` (max/mean of per-rank row reads)
and ``serve_matrix_skew`` (max/mean of per-owner rows served).

``MetricRegistry.to_dict()``/``save()`` give the serializable snapshot
the drivers write for ``--metrics``; ``repro.obs.validate`` checks the
cross-ledger invariants on that snapshot.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

__all__ = [
    "MetricKey",
    "MetricRegistry",
    "record_provider_stats",
    "record_cache_stats",
    "record_residency_stats",
    "record_collective_ledger",
    "record_latency",
    "record_tenancy",
    "record_coherence_report",
    "record_runtime",
    "record_reconciliation",
    "record_cachescope",
    "fold_trace",
    "imbalance",
    "load_snapshot",
]

# (name, rank, tier, phase)
MetricKey = Tuple[str, int, str, str]


def _key(name: str, rank: int, tier: str, phase: str) -> MetricKey:
    return (str(name), int(rank), str(tier), str(phase))


class MetricRegistry:
    """Counters / gauges / histograms keyed by ``(name, rank, tier,
    phase)``. Counters add, gauges overwrite, histograms accumulate raw
    observations (summarized at serialization time)."""

    def __init__(self):
        self._counters: Dict[MetricKey, float] = {}
        self._gauges: Dict[MetricKey, float] = {}
        self._hists: Dict[MetricKey, List[float]] = {}

    # ---------------- writes ----------------
    def counter(self, name: str, value: float = 1.0, *, rank: int = -1,
                tier: str = "", phase: str = "") -> None:
        k = _key(name, rank, tier, phase)
        self._counters[k] = self._counters.get(k, 0.0) + float(value)

    def gauge(self, name: str, value: float, *, rank: int = -1,
              tier: str = "", phase: str = "") -> None:
        self._gauges[_key(name, rank, tier, phase)] = float(value)

    def observe(self, name: str, values, *, rank: int = -1,
                tier: str = "", phase: str = "") -> None:
        k = _key(name, rank, tier, phase)
        bucket = self._hists.setdefault(k, [])
        if np.isscalar(values):
            bucket.append(float(values))
        else:
            bucket.extend(float(v) for v in np.asarray(values).ravel())

    # ---------------- queries ----------------
    def _match(self, store: Dict[MetricKey, object], name: Optional[str],
               rank: Optional[int], tier: Optional[str],
               phase: Optional[str]) -> Iterator[Tuple[MetricKey, object]]:
        for k, v in store.items():
            if name is not None and k[0] != name:
                continue
            if rank is not None and k[1] != rank:
                continue
            if tier is not None and k[2] != tier:
                continue
            if phase is not None and k[3] != phase:
                continue
            yield k, v

    def get_counter(self, name: str, *, rank: int = -1, tier: str = "",
                    phase: str = "") -> float:
        return self._counters.get(_key(name, rank, tier, phase), 0.0)

    def get_gauge(self, name: str, *, rank: int = -1, tier: str = "",
                  phase: str = "") -> Optional[float]:
        return self._gauges.get(_key(name, rank, tier, phase))

    def total(self, name: str, *, rank: Optional[int] = None,
              tier: Optional[str] = None,
              phase: Optional[str] = None) -> float:
        """Sum of all counters matching the (partial) label filter."""
        return sum(
            v for _, v in self._match(self._counters, name, rank, tier, phase)
        )

    def counters(self, *, name: Optional[str] = None,
                 rank: Optional[int] = None, tier: Optional[str] = None,
                 phase: Optional[str] = None) -> Dict[MetricKey, float]:
        return dict(self._match(self._counters, name, rank, tier, phase))

    def gauges(self, *, name: Optional[str] = None,
               rank: Optional[int] = None, tier: Optional[str] = None,
               phase: Optional[str] = None) -> Dict[MetricKey, float]:
        return dict(self._match(self._gauges, name, rank, tier, phase))

    def ranks(self) -> List[int]:
        rs = {k[1] for k in self._counters} | {k[1] for k in self._gauges}
        return sorted(r for r in rs if r >= 0)

    # ---------------- serialization ----------------
    @staticmethod
    def _row(k: MetricKey, value) -> dict:
        return {"name": k[0], "rank": k[1], "tier": k[2], "phase": k[3],
                "value": value}

    def to_dict(self) -> dict:
        hists = []
        for k, obs in sorted(self._hists.items()):
            a = np.asarray(obs, np.float64)
            p50, p90, p99 = (
                np.percentile(a, [50, 90, 99], method="lower")
                if a.size else (0.0, 0.0, 0.0)
            )
            hists.append({
                "name": k[0], "rank": k[1], "tier": k[2], "phase": k[3],
                "count": int(a.size),
                "sum": float(a.sum()),
                "min": float(a.min()) if a.size else 0.0,
                "max": float(a.max()) if a.size else 0.0,
                "p50": float(p50), "p90": float(p90), "p99": float(p99),
            })
        return {
            "schema": "repro.obs.metrics/v1",
            "counters": [self._row(k, v)
                         for k, v in sorted(self._counters.items())],
            "gauges": [self._row(k, v)
                       for k, v in sorted(self._gauges.items())],
            "histograms": hists,
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)


def load_snapshot(path: str) -> dict:
    with open(path) as f:
        snap = json.load(f)
    if snap.get("schema") != "repro.obs.metrics/v1":
        raise ValueError(f"{path}: not a repro.obs metrics snapshot")
    return snap


# --------------------------------------------------------------------------
# Adapters over the existing ledgers. All duck-typed on attribute names so
# repro.obs stays import-clean of the rest of the package (no cycles).
# --------------------------------------------------------------------------

def _record_dataclass_counters(reg: MetricRegistry, stats, *, rank: int,
                               tier: str, phase: str = "") -> None:
    for f in dataclasses.fields(stats):
        v = getattr(stats, f.name)
        if isinstance(v, (int, float, np.integer, np.floating)):
            reg.counter(f.name, float(v), rank=rank, tier=tier, phase=phase)


def record_provider_stats(reg: MetricRegistry, stats, *,
                          rank: int = -1) -> None:
    """One rank's ``ProviderStats`` → ``host``-tier counters (transport:
    local/remote reads, host-cache hits/misses, device-tier hits,
    modeled comm seconds)."""
    _record_dataclass_counters(reg, stats, rank=rank, tier="host")
    # row_requests is the invariant anchor: every row the rank asked for,
    # however it was resolved (locally, device tier, host cache, or wire).
    reg.counter("row_requests", stats.local_reads + stats.remote_reads,
                rank=rank, tier="host", phase="fetch_rows")
    # per-tenant transport attribution (dict fields are skipped by the
    # generic dataclass walk above, so flatten them here).
    for t, n in getattr(stats, "tenant_requests", {}).items():
        reg.counter(f"tenant_requests:{t}", n, rank=rank, tier="host")
    for t, b in getattr(stats, "tenant_bytes_fetched", {}).items():
        reg.counter(f"tenant_bytes_fetched:{t}", b, rank=rank, tier="host")


def record_cache_stats(reg: MetricRegistry, stats, *, rank: int = -1,
                       tier: str = "host_cache") -> None:
    """``CacheStats`` (CLaMPI layer) → ``host_cache``-tier counters."""
    _record_dataclass_counters(reg, stats, rank=rank, tier=tier)


def record_residency_stats(reg: MetricRegistry, stats, *,
                           rank: int = -1) -> None:
    """``ResidencyStats`` (device-resident hot-row tier) → ``device``."""
    _record_dataclass_counters(reg, stats, rank=rank, tier="device")


def record_collective_ledger(reg: MetricRegistry, ledger) -> None:
    """``CollectiveLedger`` → ``wire``-tier *measured* counters, keyed to
    the ``all_to_all`` phase, plus per-owner served-row counters."""
    reg.counter("rma_rows_measured", float(ledger.rows_shipped.sum()),
                tier="wire", phase="all_to_all")
    reg.counter("rma_bytes_measured", float(ledger.bytes_payload),
                tier="wire", phase="all_to_all")
    reg.counter("bytes_on_wire", float(ledger.bytes_on_wire),
                tier="wire", phase="all_to_all")
    reg.counter("n_collectives", float(ledger.n_collectives),
                tier="wire", phase="all_to_all")
    reg.counter("n_pairs", float(ledger.n_pairs),
                tier="wire", phase="all_to_all")
    reg.counter("device_wall_s", float(ledger.device_wall_s),
                tier="wire", phase="all_to_all")
    # The async data plane's savings ledgers: what the width-bucketed
    # collectives stopped padding onto the wire, and what the resident
    # device buffer stopped re-uploading (getattr: tolerate pre-async
    # ledger shims in tests).
    reg.counter("bytes_on_wire_single",
                float(getattr(ledger, "bytes_on_wire_single", 0)),
                tier="wire", phase="all_to_all")
    reg.counter("wire_padding_saved",
                float(getattr(ledger, "wire_padding_saved", 0)),
                tier="wire", phase="all_to_all")
    reg.counter("bytes_uploaded",
                float(getattr(ledger, "bytes_uploaded", 0)),
                tier="wire", phase="spmd_patch")
    reg.counter("upload_bytes_saved",
                float(getattr(ledger, "upload_bytes_saved", 0)),
                tier="wire", phase="spmd_patch")
    reg.counter("spmd_patches", float(getattr(ledger, "n_patches", 0)),
                tier="wire", phase="spmd_patch")
    reg.counter("overlap_wait_s",
                float(getattr(ledger, "overlap_wait_s", 0.0)),
                tier="wire", phase="spmd_overlap_wait")
    served = np.asarray(ledger.rows_shipped).sum(axis=1)
    for k in range(served.size):
        reg.counter("rows_served_measured", float(served[k]), rank=k,
                    tier="wire", phase="all_to_all")


def record_latency(reg: MetricRegistry, recorder, *, rank: int = -1) -> None:
    """``LatencyRecorder`` → ``serving``-tier histograms (overall and
    per SLO class) + shed counters by reason."""
    reg.observe("latency_s", recorder._lat, rank=rank, tier="serving")
    reg.counter("wall_s", recorder.wall_s, rank=rank, tier="serving",
                phase="scheduler_flush")
    for reason, n in recorder.sheds.items():
        reg.counter(f"shed_{reason}", n, rank=rank, tier="serving")
    for cls, lats in getattr(recorder, "by_class", lambda: {})().items():
        reg.observe(f"latency_s:{cls}", lats, rank=rank, tier="serving")
    # SLO attainment (only recorders that saw deadline-stamped queries
    # carry violations; pre-SLO recorders default to zero).
    reg.counter("slo_violations", getattr(recorder, "slo_violations", 0),
                rank=rank, tier="serving")
    summ = recorder.summary()
    reg.gauge("slo_hit_rate", summ.slo_hit_rate, rank=rank, tier="serving")


def record_tenancy(reg: MetricRegistry, quotas, runtime=None, *,
                   rank: int = -1) -> None:
    """``TenantQuotas`` (+ optionally the runtime's per-rank caches) →
    ``serving``/``host_cache`` tenancy counters and gauges: global
    admission outcomes, per-tenant token-bucket levels, and — when a
    cached runtime is passed — per-tenant resident cache bytes, whose
    sum equals each cache's ``used_bytes`` (the accounting invariant
    the traffic bench asserts)."""
    for outcome, per_tenant in quotas.counters().items():
        reg.counter(f"quota_{outcome}", sum(per_tenant.values()),
                    rank=rank, tier="serving")
        for t, n in per_tenant.items():
            reg.counter(f"quota_{outcome}:{t}", n, rank=rank,
                        tier="serving")
    for t, lvl in quotas.bucket_levels().items():
        reg.gauge(f"quota_tokens:{t}", lvl, rank=rank, tier="serving")
    for t, share in quotas.cache_shares().items():
        reg.gauge(f"cache_share:{t}", share, rank=rank, tier="host_cache")
    caches = getattr(runtime, "caches", None) if runtime is not None else None
    if caches is not None:
        for r, c in enumerate(caches):
            for t, b in sorted(c.tenant_bytes().items()):
                reg.counter(f"tenant_cache_bytes:{t or '_untagged'}", b,
                            rank=r, tier="host_cache")


def record_coherence_report(reg: MetricRegistry, report) -> None:
    """Streaming ``CoherenceReport`` → ``host_cache`` counters under the
    ``delta_replay`` phase."""
    _record_dataclass_counters(reg, report, rank=-1, tier="host_cache",
                               phase="delta_replay")


def record_runtime(reg: MetricRegistry, runtime) -> None:
    """The whole ``ShardedRuntime``: per-rank provider + cache stats,
    device-tier stats, the modeled serve matrix, and the derived
    placement gauges (``load_imbalance``, ``serve_matrix_skew``)."""
    for rank, st in enumerate(runtime.stats):
        record_provider_stats(reg, st, rank=rank)
    if runtime.caches is not None:
        for rank, c in enumerate(runtime.caches):
            record_cache_stats(reg, c.stats, rank=rank)
    for dev in getattr(runtime, "device_views", lambda: [])():
        # replicated: one view at rank -1; per_rank: one per rank
        record_residency_stats(reg, dev.stats,
                               rank=getattr(dev, "rank", -1))

    serve = np.asarray(runtime.serve_rows, np.float64)
    reg.counter("rma_rows_modeled", float(serve.sum()),
                tier="wire", phase="fetch_rows")
    reg.counter("rma_bytes_modeled",
                float(sum(s.bytes_fetched for s in runtime.stats)),
                tier="wire", phase="fetch_rows")
    for k in range(serve.shape[0]):
        reg.counter("rows_served_modeled", float(serve[k].sum()), rank=k,
                    tier="wire", phase="fetch_rows")

    # Placement gauges (ROADMAP items 1/2): how evenly reads land on
    # ranks, and how evenly owners shoulder the serving load.
    loads = np.asarray(
        [s.local_reads + s.remote_reads for s in runtime.stats], np.float64
    )
    reg.gauge("load_imbalance", imbalance(loads), tier="host")
    for rank in range(loads.size):
        reg.gauge("row_reads", loads[rank], rank=rank, tier="host")
    reg.gauge("serve_matrix_skew", imbalance(serve.sum(axis=1)),
              tier="wire")

    # Online repartitioning (core.repartition): how often ownership
    # moved and how many rows changed hands — zero on static runs.
    reg.counter("partition_migrations",
                int(getattr(runtime, "migrations", 0)),
                tier="host", phase="migrate")
    reg.counter("rows_migrated",
                int(getattr(runtime, "rows_migrated", 0)),
                tier="host", phase="migrate")


def imbalance(per_rank) -> float:
    """max/mean over a per-rank load vector — 1.0 is perfectly balanced;
    0.0 when there is no load at all (so a populated gauge always means
    "measured")."""
    per_rank = np.asarray(per_rank, np.float64)
    m = float(per_rank.mean()) if per_rank.size else 0.0
    return float(per_rank.max()) / m if m > 0 else 0.0


def record_reconciliation(reg: MetricRegistry, runtime,
                          ledger=None) -> None:
    """Measured-vs-modeled RMA reconciliation as a first-class metric.

    The modeled side is the runtime's serve matrix / ``bytes_fetched``
    (what the 1D-partition cost model says must move); the measured side
    is the ``CollectiveLedger`` (what the SPMD all_to_all actually
    shipped, payload-true). ``rma_agreement`` is 1.0 iff both rows and
    bytes agree exactly — the same invariant the SPMD engine asserts per
    microbatch, now exported and CI-validated end to end."""
    modeled_rows = float(np.asarray(runtime.serve_rows).sum())
    modeled_bytes = float(sum(s.bytes_fetched for s in runtime.stats))
    if ledger is None:
        return
    measured_rows = float(ledger.rows_shipped.sum())
    measured_bytes = float(ledger.bytes_payload)
    agree = (measured_rows == modeled_rows
             and measured_bytes == modeled_bytes)
    reg.gauge("rma_agreement", 1.0 if agree else 0.0, tier="wire")
    reg.gauge("rma_bytes_delta", measured_bytes - modeled_bytes,
              tier="wire")
    reg.gauge("rma_rows_delta", measured_rows - modeled_rows, tier="wire")


def record_cachescope(reg: MetricRegistry, report: dict) -> None:
    """A cachescope analysis report (``repro.obs.cachescope/v1``) →
    per-stream gauges and per-policy replay counters. Gauges answer the
    cache-science questions directly from a metrics snapshot: did the
    replay reconcile, how premature are evictions, what would each
    policy have scored on this exact trace, and how far is the deployed
    policy from the clairvoyant bound."""
    for s in report["streams"]:
        tier = s["tier"]
        rank = int(s["rank"])
        reg.gauge("cachescope_reconciled",
                  1.0 if s["reconciled"] else 0.0, rank=rank, tier=tier)
        a = s["analysis"]
        audit = a.get("eviction_audit")
        if audit and audit["n_evictions"]:
            reg.gauge("premature_eviction_frac", audit["reref_frac"],
                      rank=rank, tier=tier)
            reg.counter("bytes_evicted_reref", audit["bytes_evicted_live"],
                        rank=rank, tier=tier)
        for pol, rep in s.get("replay", {}).items():
            if "hit_rate" in rep:
                reg.gauge(f"replay_hit_rate:{pol}", rep["hit_rate"],
                          rank=rank, tier=tier)
    summ = report["summary"]
    reg.gauge("cachescope_reconciled_all",
              1.0 if summ["all_reconciled"] else 0.0, tier="host_cache")
    reg.gauge("cachescope_belady_dominates",
              1.0 if summ["belady_dominates"] else 0.0, tier="host_cache")


def fold_trace(reg: MetricRegistry, tracer) -> None:
    """Fold a ``Tracer``'s per-phase rollup into the registry: wall
    seconds, call counts, and byte-tagged volume per phase name. This is
    the bridge that gives counters the time dimension the experiments
    report tabulates."""
    for name, d in tracer.phase_totals().items():
        reg.counter("phase_time_s", d["total_s"], phase=name)
        reg.counter("phase_calls", d["calls"], phase=name)
        if d["bytes"]:
            reg.counter("phase_bytes", d["bytes"], phase=name)
