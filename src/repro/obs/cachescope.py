"""Cache-science observability: access traces + analytics for both tiers.

The paper's caching claims (§III-B, Observations 3.1/3.2: degree
predicts reuse; Fig. 7/8: hit rate vs capacity and score policy) are
*why* questions, but ``CacheStats``/``ResidencyStats`` only answer
*what*. This module records the per-access event stream of every cache
instance — host ``ClampiCache`` and device ``ResidencyManager`` — and
turns one recorded run into the full cache-science picture:

1. **Recorder** (``enable_recording``/``disable_recording``): the same
   near-zero-overhead pattern as ``obs.trace`` — each hook in the cache
   hot paths is one module-global load + ``None`` check when disabled.
   Streams are keyed per cache instance and labeled ``(tier, rank,
   label)``; host streams log ``get``/``evict``/``invalidate``/
   ``flush``/``close_epoch`` events (key, size, score at access, hit),
   device streams log lookups and membership changes
   (``reset``/``admit``/``evict``/``patch``).

2. **Reuse-distance analytics** (``reuse_distances``): a one-pass
   Mattson stack-distance computation (Fenwick tree over access
   positions, one counting entries and one counting bytes) yielding,
   from a single run, the LRU hit-rate-vs-capacity curve at *every*
   capacity — what previously took one full run per cache size
   (``bench_cache_size``). Invalidations remove the key from the stack
   (its next access is a compulsory re-miss); flushes clear it. The
   byte-distance curve is exact for ideal LRU at capacities >= the
   largest entry on invalidation-free traces (entry sizes are constant
   between invalidations — the runtime invalidates before any row
   mutation becomes visible); ``spot_checks`` verify it against a
   direct LRU simulation.

3. **Eviction-quality audit** (``eviction_audit``): fraction of evicted
   victims re-referenced within k accesses ("premature evictions"),
   overall and per policy-score decile, plus the byte-denominated
   counterpart that ``CacheStats.bytes_evicted_live`` tracks live.

4. **Offline policy replay** (``replay_host``/``replay_belady``): the
   same trace re-run under the deployed policy, pure LRU, degree
   (size-proportional) score, frequency-EWMA score, and a clairvoyant
   Belady upper bound. The hard invariant — checked by ``analyze`` and
   re-checked by ``repro.obs.validate`` on the exported sidecar — is
   that the *deployed*-policy replay reproduces the live ``CacheStats``
   deltas (gets/hits/misses/evictions/...) bit-exactly: the recorded
   stream provably contains everything the cache decided on.

Results flow into the ``MetricRegistry`` via
``metrics.record_cachescope`` and export as a ``.cachescope.json``
sidecar (``save_report``/``load_report``), surfaced by ``--cache-trace``
on ``query_serve``, ``stream_run`` and ``lcc_run``.

The core/device modules import *this module object only* (to read
``_recorder``); all imports of ``repro.core`` here are lazy, inside
functions, so there is no import cycle.
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "SCHEMA",
    "CacheTraceRecorder",
    "enable_recording",
    "disable_recording",
    "get_recorder",
    "recording_enabled",
    "reuse_distances",
    "hit_curve",
    "eviction_audit",
    "replay_host",
    "replay_belady",
    "replay_device",
    "simulate_lru_bytes",
    "analyze",
    "save_report",
    "load_report",
]

SCHEMA = "repro.obs.cachescope/v1"

# host-stream CacheStats fields the deployed replay must reproduce
# bit-exactly (all integers; comm_time is float and excluded because a
# warm-start baseline shifts the accumulation order).
HOST_COMPARE = (
    "gets", "hits", "misses", "evictions", "invalidations", "flushes",
    "bytes_hit", "bytes_missed",
)
# device-stream ResidencyStats fields the membership replay reproduces.
DEVICE_COMPARE = ("lookups", "hits", "misses", "admits", "evicts", "patches")


# --------------------------------------------------------------------------
# Recorder
# --------------------------------------------------------------------------

class _HostStream:
    """Event log of one ``ClampiCache`` instance.

    Columnar parallel arrays; ``kinds[i]`` is one of ``"g"`` (get),
    ``"e"`` (evict victim), ``"i"`` (invalidate), ``"f"`` (flush),
    ``"c"`` (close_epoch). Non-get events carry ``key=-1``/``size=0``
    where not meaningful; ``scores`` holds None for unscored events.
    """

    __slots__ = ("tier", "rank", "label", "config", "preload", "baseline",
                 "kinds", "keys", "sizes", "scores", "hits", "cache")

    def __init__(self, cache):
        self.tier = "host_cache"
        self.rank = int(getattr(cache, "rank", -1))
        self.label = str(getattr(cache, "scope_label", "clampi"))
        net = cache.net
        self.config = {
            "capacity": int(cache.capacity),
            "table_slots": int(cache.table_slots),
            "mode": cache.mode,
            "positional_weight": float(cache.positional_weight),
            "adaptive": bool(cache.adaptive),
            "alpha": float(net.alpha),
            "beta": float(net.beta),
            "hit_cost": float(net.hit_cost),
            "insert_cost": float(net.insert_cost),
        }
        # warm-start snapshot: a cache registered mid-life replays from
        # its state at registration, not from empty.
        self.preload = None
        if cache.entries or cache.clock:
            self.preload = {
                "clock": int(cache.clock),
                "free": [[int(a), int(s)] for a, s in cache.free],
                "entries": [
                    [int(e.key), int(e.addr), int(e.size), int(e.last_use),
                     (None if e.score is None else float(e.score))]
                    for e in cache.entries.values()
                ],
            }
        self.baseline = _stats_dict(cache.stats)
        self.kinds: List[str] = []
        self.keys: List[int] = []
        self.sizes: List[int] = []
        self.scores: List[Optional[float]] = []
        self.hits: List[int] = []
        self.cache = cache

    def push(self, kind: str, key: int, size: int,
             score: Optional[float], hit: bool) -> None:
        self.kinds.append(kind)
        self.keys.append(int(key))
        self.sizes.append(int(size))
        self.scores.append(None if score is None else float(score))
        self.hits.append(1 if hit else 0)

    def live_delta(self) -> Dict[str, float]:
        now = _stats_dict(self.cache.stats)
        return {k: now[k] - self.baseline.get(k, 0) for k in now}

    def to_doc(self) -> dict:
        # rank/scope_label tags may be attached after the first recorded
        # event (e.g. right after construction) — re-read at export
        return {
            "tier": self.tier,
            "rank": int(getattr(self.cache, "rank", self.rank)),
            "label": str(getattr(self.cache, "scope_label", self.label)),
            "config": self.config,
            "preload": self.preload,
            "events": {
                "kinds": "".join(self.kinds),
                "keys": self.keys,
                "sizes": self.sizes,
                "scores": self.scores,
                "hits": self.hits,
            },
            "live": self.live_delta(),
        }


class _DeviceStream:
    """Event log of one ``ResidencyManager``.

    ``events`` is a list of ``[kind, payload]``: ``["r", [ids...]]``
    (reset: membership becomes exactly ids), ``["l", [ids...]]``
    (lookup batch), ``["a", v]`` (admit), ``["e", v]`` (evict),
    ``["p", v]`` (in-place patch; membership unchanged).
    """

    __slots__ = ("tier", "rank", "label", "config", "preload", "baseline",
                 "events", "mgr")

    def __init__(self, mgr):
        self.tier = "device"
        self.rank = int(getattr(mgr, "rank", -1))
        self.label = str(getattr(mgr, "scope_label", "residency"))
        self.config = {"slots": int(mgr.slots),
                       "max_width": int(mgr.max_width)}
        ids = np.asarray(mgr.slot_ids)
        self.preload = [int(v) for v in ids[ids >= 0]]
        self.baseline = _stats_dict(mgr.stats)
        self.events: List[list] = []
        self.mgr = mgr

    def live_delta(self) -> Dict[str, float]:
        now = _stats_dict(self.mgr.stats)
        return {k: now[k] - self.baseline.get(k, 0) for k in now}

    def to_doc(self) -> dict:
        return {
            "tier": self.tier,
            "rank": int(getattr(self.mgr, "rank", self.rank)),
            "label": str(getattr(self.mgr, "scope_label", self.label)),
            "config": self.config,
            "preload": self.preload,
            "events": self.events,
            "live": self.live_delta(),
        }


def _stats_dict(stats) -> Dict[str, float]:
    out = {}
    for f in dataclasses.fields(stats):
        v = getattr(stats, f.name)
        if isinstance(v, (int, np.integer)):
            out[f.name] = int(v)
        elif isinstance(v, (float, np.floating)):
            out[f.name] = float(v)
    return out


class CacheTraceRecorder:
    """Per-cache-instance event streams. Hooks in the cache hot paths
    call ``on_*``; each is a dict lookup + appends — cheap enough for
    recorded runs, and *free* when no recorder is installed (the hooks
    check the module global first)."""

    def __init__(self):
        self._host: Dict[int, _HostStream] = {}
        self._dev: Dict[int, _DeviceStream] = {}

    # ---------------- host tier ----------------
    def _h(self, cache) -> Optional[_HostStream]:
        if getattr(cache, "_scope_exempt", False):
            return None  # replay caches must not re-record themselves
        s = self._host.get(id(cache))
        if s is None:
            s = self._host[id(cache)] = _HostStream(cache)
        return s

    def touch(self, cache) -> None:
        """Register ``cache``'s stream now (before the caller mutates any
        stats), so the baseline snapshot is clean."""
        self._h(cache)

    def on_get(self, cache, key: int, size: int,
               score: Optional[float], hit: bool) -> None:
        s = self._h(cache)
        if s is not None:
            s.push("g", key, size, score, hit)

    def on_evict(self, cache, key: int, size: int,
                 score: Optional[float]) -> None:
        s = self._h(cache)
        if s is not None:
            s.push("e", key, size, score, False)

    def on_invalidate(self, cache, key: int) -> None:
        s = self._h(cache)
        if s is not None:
            s.push("i", key, 0, None, False)

    def on_flush(self, cache) -> None:
        s = self._h(cache)
        if s is not None:
            s.push("f", -1, 0, None, False)

    def on_close_epoch(self, cache) -> None:
        s = self._h(cache)
        if s is not None:
            s.push("c", -1, 0, None, False)

    # ---------------- device tier ----------------
    def _d(self, mgr) -> _DeviceStream:
        s = self._dev.get(id(mgr))
        if s is None:
            s = self._dev[id(mgr)] = _DeviceStream(mgr)
        return s

    def on_dev_reset(self, mgr, ids) -> None:
        self._d(mgr).events.append(
            ["r", [int(v) for v in np.asarray(ids).ravel()]])

    def on_dev_lookup(self, mgr, ids) -> None:
        self._d(mgr).events.append(
            ["l", [int(v) for v in np.asarray(ids).ravel()]])

    def on_dev_admit(self, mgr, v: int) -> None:
        self._d(mgr).events.append(["a", int(v)])

    def on_dev_evict(self, mgr, v: int) -> None:
        self._d(mgr).events.append(["e", int(v)])

    def on_dev_patch(self, mgr, v: int) -> None:
        self._d(mgr).events.append(["p", int(v)])

    # ---------------- access ----------------
    def host_streams(self) -> List[_HostStream]:
        return list(self._host.values())

    def device_streams(self) -> List[_DeviceStream]:
        return list(self._dev.values())

    def n_events(self) -> int:
        return (sum(len(s.kinds) for s in self._host.values())
                + sum(len(s.events) for s in self._dev.values()))


# module-level switchboard (same contract as obs.trace._tracer): the
# cache hot paths read `_recorder` directly — one global load + None
# check when recording is off.
_recorder: Optional[CacheTraceRecorder] = None


def enable_recording() -> CacheTraceRecorder:
    """Install (and return) a fresh global cache-trace recorder."""
    global _recorder
    _recorder = CacheTraceRecorder()
    return _recorder


def disable_recording() -> Optional[CacheTraceRecorder]:
    """Remove the global recorder; returns it (streams intact) if any."""
    global _recorder
    r, _recorder = _recorder, None
    return r


def get_recorder() -> Optional[CacheTraceRecorder]:
    return _recorder


def recording_enabled() -> bool:
    return _recorder is not None


# --------------------------------------------------------------------------
# Reuse distances (one-pass Mattson) + hit-rate-vs-capacity curve
# --------------------------------------------------------------------------

class _Fenwick:
    """Prefix-sum tree over access positions (1-indexed)."""

    __slots__ = ("n", "t")

    def __init__(self, n: int):
        self.n = n
        self.t = [0] * (n + 1)

    def add(self, i: int, x: int) -> None:
        i += 1
        while i <= self.n:
            self.t[i] += x
            i += i & (-i)

    def prefix(self, i: int) -> int:  # sum of [0, i]
        i += 1
        s = 0
        while i > 0:
            s += self.t[i]
            i -= i & (-i)
        return s

    def range(self, lo: int, hi: int) -> int:  # sum of [lo, hi]
        if hi < lo:
            return 0
        return self.prefix(hi) - (self.prefix(lo - 1) if lo > 0 else 0)


def _host_events(doc_or_stream):
    """Normalize a _HostStream or its exported doc to parallel arrays."""
    if isinstance(doc_or_stream, _HostStream):
        return (doc_or_stream.kinds, doc_or_stream.keys,
                doc_or_stream.sizes, doc_or_stream.scores,
                doc_or_stream.hits)
    ev = doc_or_stream["events"] if "events" in doc_or_stream else doc_or_stream
    return (list(ev["kinds"]), ev["keys"], ev["sizes"], ev["scores"],
            ev["hits"])


def reuse_distances(stream, *, mode: str = "always") -> Dict[str, Any]:
    """One-pass Mattson stack distances over a host event stream.

    For each get: ``dist_entries`` = number of *distinct* keys accessed
    since this key's previous access (inclusive of itself) and
    ``dist_bytes`` = their byte footprint — the LRU stack depth the
    access lands at. ``-1`` encodes infinity (first access, or access
    after an invalidation/flush of the key). Under ideal LRU the access
    hits a cache of ``C`` slots iff ``dist_entries <= C`` and a cache of
    ``B`` bytes iff ``dist_bytes <= B`` (exact for ``B`` >= the largest
    entry; entry sizes are constant between invalidations).
    """
    kinds, keys, sizes, _scores, _hits = _host_events(stream)
    n_gets = sum(1 for k in kinds if k == "g")
    bit_cnt = _Fenwick(n_gets)
    bit_bytes = _Fenwick(n_gets)
    last: Dict[int, Tuple[int, int]] = {}  # key -> (pos, size)
    dist_e = np.full(n_gets, -1, np.int64)
    dist_b = np.full(n_gets, -1, np.int64)
    out_sizes = np.zeros(n_gets, np.int64)
    pos = 0
    had_inval = False
    transparent = mode == "transparent"
    for i, kind in enumerate(kinds):
        if kind == "g":
            key, size = keys[i], sizes[i]
            prev = last.get(key)
            if prev is not None:
                p0, s0 = prev
                dist_e[pos] = 1 + bit_cnt.range(p0 + 1, pos - 1)
                dist_b[pos] = size + bit_bytes.range(p0 + 1, pos - 1)
                bit_cnt.add(p0, -1)
                bit_bytes.add(p0, -s0)
            bit_cnt.add(pos, 1)
            bit_bytes.add(pos, size)
            last[key] = (pos, size)
            out_sizes[pos] = size
            pos += 1
        elif kind == "i":
            prev = last.pop(keys[i], None)
            if prev is not None:
                bit_cnt.add(prev[0], -1)
                bit_bytes.add(prev[0], -prev[1])
            had_inval = True
        elif kind == "f" or (kind == "c" and transparent):
            for p0, s0 in last.values():
                bit_cnt.add(p0, -1)
                bit_bytes.add(p0, -s0)
            last.clear()
    return {
        "dist_entries": dist_e,
        "dist_bytes": dist_b,
        "sizes": out_sizes,
        "n_gets": n_gets,
        "had_invalidations": had_inval,
        "max_entry_bytes": int(out_sizes.max()) if n_gets else 0,
    }


def hit_curve(dist: np.ndarray, capacities) -> np.ndarray:
    """Hits at each capacity from a distance array (-1 = never hits)."""
    finite = np.sort(dist[dist >= 0])
    caps = np.asarray(list(capacities), np.int64)
    return np.searchsorted(finite, caps, side="right")


def _log2_hist(dist: np.ndarray) -> Dict[str, Any]:
    """Log2-bucketed histogram of reuse distances; bucket b counts
    distances in [2^b, 2^(b+1))."""
    finite = dist[dist >= 0]
    inf = int((dist < 0).sum())
    if finite.size == 0:
        return {"log2_counts": [], "inf": inf, "n": int(dist.size)}
    b = np.floor(np.log2(np.maximum(finite, 1))).astype(np.int64)
    counts = np.bincount(b).tolist()
    return {"log2_counts": [int(c) for c in counts], "inf": inf,
            "n": int(dist.size)}


def simulate_lru_bytes(stream, capacity: int, *,
                       mode: str = "always") -> Tuple[int, int]:
    """Direct ideal-LRU byte-capacity simulation (no fragmentation, no
    table-slot limit) — the ground truth the Mattson curve is
    spot-checked against. Returns (hits, misses)."""
    from collections import OrderedDict

    kinds, keys, sizes, _scores, _hits = _host_events(stream)
    res: "OrderedDict[int, int]" = OrderedDict()
    used = 0
    hits = misses = 0
    transparent = mode == "transparent"
    for i, kind in enumerate(kinds):
        if kind == "g":
            key, size = keys[i], sizes[i]
            old = res.get(key)
            if old is not None:
                res.move_to_end(key)
                if old != size:  # defensive; sizes are stable in practice
                    used += size - old
                    res[key] = size
                hits += 1
                continue
            misses += 1
            if size > capacity:
                continue
            while used + size > capacity:
                _k, s0 = res.popitem(last=False)
                used -= s0
            res[key] = size
            used += size
        elif kind == "i":
            s0 = res.pop(keys[i], None)
            if s0 is not None:
                used -= s0
        elif kind == "f" or (kind == "c" and transparent):
            res.clear()
            used = 0
    return hits, misses


# --------------------------------------------------------------------------
# Eviction-quality audit
# --------------------------------------------------------------------------

def eviction_audit(stream, *, ks: Tuple[int, ...] = (64, 1024)) -> dict:
    """Were evictions premature? For every recorded victim, find its
    next re-reference (in get-stream positions); report the fraction
    re-referenced ever and within each window ``k``, overall and per
    policy-score decile, plus the byte-denominated totals (the offline
    counterpart of ``CacheStats.bytes_evicted_live``)."""
    kinds, keys, sizes, scores, _hits = _host_events(stream)
    access_pos: Dict[int, List[int]] = {}
    pos = 0
    evs: List[Tuple[int, int, int, Optional[float]]] = []  # (pos, key, size, score)
    for i, kind in enumerate(kinds):
        if kind == "g":
            access_pos.setdefault(keys[i], []).append(pos)
            pos += 1
        elif kind == "e":
            evs.append((pos, keys[i], sizes[i], scores[i]))
    gaps: List[float] = []  # accesses until re-reference (inf if never)
    bytes_evicted = 0
    bytes_live = 0
    for at, key, size, _sc in evs:
        bytes_evicted += size
        nxt = access_pos.get(key)
        j = bisect.bisect_left(nxt, at) if nxt else 0
        if nxt and j < len(nxt):
            gaps.append(float(nxt[j] - at + 1))
            bytes_live += size
        else:
            gaps.append(math.inf)
    g = np.asarray(gaps, np.float64)
    n = len(evs)
    out = {
        "n_evictions": n,
        "reref_frac": float((g < math.inf).mean()) if n else 0.0,
        "premature_within_k": {
            str(k): (float((g <= k).mean()) if n else 0.0) for k in ks
        },
        "bytes_evicted": int(bytes_evicted),
        "bytes_evicted_live": int(bytes_live),
    }
    # per score decile: does a low policy score actually predict no
    # re-reference? (paper Obs. 3.1/3.2 quality check for the score fn)
    sc = np.asarray(
        [s if s is not None else np.nan for (_p, _k, _s, s) in
         ((e[0], e[1], e[2], e[3]) for e in evs)], np.float64)
    scored = ~np.isnan(sc)
    deciles = []
    if scored.sum() >= 10:
        edges = np.quantile(sc[scored], np.linspace(0, 1, 11))
        which = np.clip(
            np.searchsorted(edges, sc[scored], side="right") - 1, 0, 9)
        gg = g[scored]
        kmax = max(ks)
        for d in range(10):
            m = which == d
            deciles.append({
                "decile": d,
                "score_lo": float(edges[d]),
                "score_hi": float(edges[d + 1]),
                "n": int(m.sum()),
                "premature_frac": (
                    float((gg[m] <= kmax).mean()) if m.any() else 0.0),
            })
    out["by_score_decile"] = deciles
    return out


# --------------------------------------------------------------------------
# Offline policy replay
# --------------------------------------------------------------------------

def _build_replay_cache(cfg: dict, *, capacity=None, table_slots=None,
                        positional_weight=None, adaptive=None):
    from ..core.cache import ClampiCache, NetworkModel

    net = NetworkModel(alpha=cfg["alpha"], beta=cfg["beta"],
                       hit_cost=cfg["hit_cost"],
                       insert_cost=cfg["insert_cost"])
    c = ClampiCache(
        int(capacity if capacity is not None else cfg["capacity"]),
        int(table_slots if table_slots is not None else cfg["table_slots"]),
        mode=cfg["mode"],
        positional_weight=(cfg["positional_weight"]
                           if positional_weight is None
                           else positional_weight),
        adaptive=(cfg["adaptive"] if adaptive is None else adaptive),
        network=net,
    )
    c._scope_exempt = True  # never re-record a replay
    return c


def _restore_preload(cache, preload: Optional[dict]) -> None:
    if not preload:
        return
    from ..core.cache import _Entry

    cache.clock = int(preload["clock"])
    cache.free = [(int(a), int(s)) for a, s in preload["free"]]
    for key, addr, size, last_use, score in preload["entries"]:
        cache.entries[int(key)] = _Entry(
            int(key), int(addr), int(size), int(last_use),
            None if score is None else float(score))
        cache._seen.add(int(key))


def replay_host(stream, *, policy: str = "deployed",
                capacity: Optional[int] = None,
                table_slots: Optional[int] = None,
                positional_weight: Optional[float] = None,
                ewma_decay: float = 0.98) -> Dict[str, float]:
    """Re-run a recorded host stream through a fresh ``ClampiCache``.

    Policies rewrite only the score each get carries:

    - ``"deployed"`` — the recorded score, recorded positional weight:
      by cache determinism this MUST reproduce the live stats deltas
      bit-exactly (the reconciliation invariant).
    - ``"lru"`` — no score, positional weight 0 (pure LRU).
    - ``"lru_positional"`` — no score, recorded positional weight
      (CLaMPI's default victim selection).
    - ``"degree"`` — score = entry byte size (proportional to degree
      for adjacency rows; the paper's application score reconstructed
      from the trace alone).
    - ``"ewma"`` — frequency-EWMA score: on each access of ``key``,
      ``f = 1 + f_prev * decay**(gap)`` (gap in accesses) — the live-
      workload score ROADMAP item 4 wants to blend with degree.
    """
    kinds, keys, sizes, scores, _hits = _host_events(stream)
    cfg = stream.config if isinstance(stream, _HostStream) else stream["config"]
    preload = (stream.preload if isinstance(stream, _HostStream)
               else stream.get("preload"))
    if policy == "lru":
        positional_weight = 0.0 if positional_weight is None else positional_weight
    cache = _build_replay_cache(cfg, capacity=capacity,
                                table_slots=table_slots,
                                positional_weight=positional_weight)
    _restore_preload(cache, preload)
    freq: Dict[int, Tuple[float, int]] = {}  # key -> (f, last access idx)
    t = 0
    for i, kind in enumerate(kinds):
        if kind == "g":
            key, size = keys[i], sizes[i]
            t += 1
            if policy == "deployed":
                score = scores[i]
            elif policy in ("lru", "lru_positional"):
                score = None
            elif policy == "degree":
                score = float(size)
            elif policy == "ewma":
                f_prev, t_prev = freq.get(key, (0.0, t))
                f = 1.0 + f_prev * (ewma_decay ** (t - t_prev))
                freq[key] = (f, t)
                score = f
            else:
                raise ValueError(f"unknown replay policy {policy!r}")
            cache.get(key, size, score=score)
        elif kind == "i":
            cache.invalidate(keys[i])
        elif kind == "f":
            cache.flush()
        elif kind == "c":
            cache.close_epoch()
        # "e" events are the deployed cache's own decisions — a replay
        # makes its own.
    out = _stats_dict(cache.stats)
    out["policy"] = policy
    out["hit_rate"] = out["hits"] / out["gets"] if out["gets"] else 0.0
    return out


def replay_belady(stream, *, capacity: Optional[int] = None) -> Dict[str, float]:
    """Clairvoyant upper bound: byte-capacity cache with perfect
    knowledge of the future — never admits a never-again-referenced
    entry, evicts the resident with the farthest next use. No
    fragmentation or table-slot limits, so it upper-bounds what any
    practical policy in this memory system can reach."""
    kinds, keys, sizes, _scores, _hits = _host_events(stream)
    cfg = stream.config if isinstance(stream, _HostStream) else stream["config"]
    cap = int(capacity if capacity is not None else cfg["capacity"])
    transparent = cfg["mode"] == "transparent"
    # next-use chain over get positions
    n_gets = sum(1 for k in kinds if k == "g")
    nxt = np.full(n_gets, np.iinfo(np.int64).max, np.int64)
    last_seen: Dict[int, int] = {}
    pos = n_gets
    for i in range(len(kinds) - 1, -1, -1):
        if kinds[i] == "g":
            pos -= 1
            key = keys[i]
            if key in last_seen:
                nxt[pos] = last_seen[key]
            last_seen[key] = pos
    res: Dict[int, Tuple[int, int]] = {}  # key -> (size, next_use)
    used = 0
    hits = misses = evictions = 0
    pos = 0
    inf = np.iinfo(np.int64).max
    for i, kind in enumerate(kinds):
        if kind == "g":
            key, size = keys[i], sizes[i]
            nu = int(nxt[pos])
            pos += 1
            if key in res:
                hits += 1
                res[key] = (size, nu)
                continue
            misses += 1
            if size > cap or nu == inf:
                continue  # clairvoyant bypass: no future benefit
            admitted = True
            while used + size > cap:
                victim = max(res, key=lambda k: res[k][1])
                if res[victim][1] <= nu:
                    admitted = False  # everything resident is more useful
                    break
                used -= res.pop(victim)[0]
                evictions += 1
            if not admitted:
                continue
            res[key] = (size, nu)
            used += size
        elif kind == "i":
            s0 = res.pop(keys[i], None)
            if s0 is not None:
                used -= s0[0]
        elif kind == "f" or (kind == "c" and transparent):
            res.clear()
            used = 0
    gets = hits + misses
    return {"policy": "belady", "gets": gets, "hits": hits,
            "misses": misses, "evictions": evictions,
            "hit_rate": hits / gets if gets else 0.0}


def replay_device(stream) -> Dict[str, int]:
    """Membership-set replay of a device stream: derive lookup
    hits/misses and membership-change counts from the event log alone;
    reconciles against the live ``ResidencyStats`` deltas."""
    preload = (stream.preload if isinstance(stream, _DeviceStream)
               else stream["preload"])
    events = (stream.events if isinstance(stream, _DeviceStream)
              else stream["events"])
    member = set(int(v) for v in preload)
    lookups = hits = misses = admits = evicts = patches = 0
    for kind, payload in events:
        if kind == "l":
            lookups += len(payload)
            h = sum(1 for v in payload if v in member)
            hits += h
            misses += len(payload) - h
        elif kind == "a":
            member.add(int(payload))
            admits += 1
        elif kind == "e":
            member.discard(int(payload))
            evicts += 1
        elif kind == "p":
            patches += 1
        elif kind == "r":
            member = set(int(v) for v in payload)
    return {"lookups": lookups, "hits": hits, "misses": misses,
            "admits": admits, "evicts": evicts, "patches": patches}


# --------------------------------------------------------------------------
# Analysis report + sidecar
# --------------------------------------------------------------------------

def _spot_capacities(max_entry: int, capacity: int) -> List[int]:
    """>=3 distinct byte capacities at which the Mattson curve is
    provably exact for ideal LRU (all >= the largest entry)."""
    base = max(int(max_entry), 1)
    caps = {base, 2 * base, 4 * base, max(int(capacity), base)}
    return sorted(caps)


def _analyze_host_doc(doc: dict, *, policies, curve_points: int,
                      audit_ks) -> dict:
    mode = doc["config"]["mode"]
    dist = reuse_distances(doc, mode=mode)
    n_gets = dist["n_gets"]
    analysis: Dict[str, Any] = {
        "n_gets": n_gets,
        "reuse_hist_entries": _log2_hist(dist["dist_entries"]),
        "reuse_hist_bytes": _log2_hist(dist["dist_bytes"]),
        "had_invalidations": dist["had_invalidations"],
        "max_entry_bytes": dist["max_entry_bytes"],
    }
    if n_gets:
        cap = int(doc["config"]["capacity"])
        lo = max(dist["max_entry_bytes"], 1)
        hi = max(cap, 2 * lo)
        caps = np.unique(np.geomspace(lo, hi, curve_points).astype(np.int64))
        hits = hit_curve(dist["dist_bytes"], caps)
        analysis["mattson"] = {
            "capacities_bytes": [int(c) for c in caps],
            "hit_rate": [float(h / n_gets) for h in hits],
            "exact_model": not dist["had_invalidations"],
        }
        # exactness vs ideal LRU holds only on invalidation-free traces
        # (an entry can be evicted under pressure from bytes that are
        # later invalidated — the retroactive BIT removal can't see
        # that); with invalidations the curve is a model, not gated.
        if not dist["had_invalidations"]:
            spot = []
            for c in _spot_capacities(dist["max_entry_bytes"], cap):
                m_hits = int(hit_curve(dist["dist_bytes"], [c])[0])
                d_hits, _ = simulate_lru_bytes(doc, c, mode=mode)
                spot.append({"capacity_bytes": int(c),
                             "mattson_hits": m_hits,
                             "direct_hits": int(d_hits),
                             "match": m_hits == d_hits})
            analysis["spot_checks"] = spot
            analysis["spot_match_all"] = all(s["match"] for s in spot)
        else:
            analysis["spot_checks"] = []
            analysis["spot_match_all"] = None
    analysis["eviction_audit"] = eviction_audit(doc, ks=audit_ks)

    replay: Dict[str, dict] = {}
    for pol in policies:
        replay[pol] = replay_host(doc, policy=pol)
    replay["belady"] = replay_belady(doc)
    live = doc["live"]
    reconciled = all(
        int(live.get(k, 0)) == int(replay["deployed"].get(k, 0))
        for k in HOST_COMPARE
    )
    return {**doc, "replay": replay, "reconciled": reconciled,
            "analysis": analysis}


def _analyze_device_doc(doc: dict) -> dict:
    rep = replay_device(doc)
    live = doc["live"]
    reconciled = all(
        int(live.get(k, 0)) == int(rep.get(k, 0)) for k in DEVICE_COMPARE
    )
    # reuse distances over the lookup stream (unit-size keys): the
    # LRU-slots curve that sizes `device_slots` (docs worked example)
    lk: List[int] = []
    for kind, payload in doc["events"]:
        if kind == "l":
            lk.extend(payload)
    synth = {
        "events": {
            "kinds": "g" * len(lk),
            "keys": lk,
            "sizes": [1] * len(lk),
            "scores": [None] * len(lk),
            "hits": [0] * len(lk),
        }
    }
    dist = reuse_distances(synth)
    analysis: Dict[str, Any] = {
        "n_lookups": len(lk),
        "reuse_hist_entries": _log2_hist(dist["dist_entries"]),
    }
    if lk:
        slots_axis = np.unique(np.geomspace(
            1, max(2 * doc["config"]["slots"], 2), 12).astype(np.int64))
        hits = hit_curve(dist["dist_entries"], slots_axis)
        analysis["lru_slots_curve"] = {
            "slots": [int(s) for s in slots_axis],
            "hit_rate": [float(h / len(lk)) for h in hits],
        }
    return {**doc, "replay": {"deployed": rep}, "reconciled": reconciled,
            "analysis": analysis}


def analyze(recorder: CacheTraceRecorder, *,
            policies: Tuple[str, ...] = ("deployed", "lru", "degree", "ewma"),
            curve_points: int = 12,
            audit_ks: Tuple[int, ...] = (64, 1024)) -> dict:
    """Full cache-science report over every recorded stream: replay
    reconciliation, Mattson curves + spot checks, reuse histograms,
    eviction audits, and the policy/Belady comparison. The returned
    dict is the ``.cachescope.json`` sidecar (``save_report``)."""
    streams = []
    for hs in recorder.host_streams():
        streams.append(_analyze_host_doc(
            hs.to_doc(), policies=policies, curve_points=curve_points,
            audit_ks=audit_ks))
    for ds in recorder.device_streams():
        streams.append(_analyze_device_doc(ds.to_doc()))
    host = [s for s in streams if s["tier"] == "host_cache"]
    belady_ok = all(
        s["replay"]["belady"]["hits"] >= max(
            r["hits"] for p, r in s["replay"].items() if p != "belady")
        for s in host if s["analysis"]["n_gets"]
    )
    report = {
        "schema": SCHEMA,
        "streams": streams,
        "summary": {
            "n_streams": len(streams),
            "n_host_streams": len(host),
            "n_device_streams": len(streams) - len(host),
            "all_reconciled": all(s["reconciled"] for s in streams),
            "belady_dominates": belady_ok,
        },
    }
    return report


def save_report(report: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report, f, separators=(",", ":"))


def load_report(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != SCHEMA:
        raise ValueError(f"{path}: not a {SCHEMA} sidecar")
    return doc


def summarize(report: dict) -> str:
    """One-paragraph human summary for the launch drivers."""
    s = report["summary"]
    lines = [
        f"cachescope: {s['n_host_streams']} host + "
        f"{s['n_device_streams']} device stream(s), "
        f"replay reconciled: {'EXACT' if s['all_reconciled'] else 'MISMATCH'}"
        f", belady dominates: {s['belady_dominates']}"
    ]
    for st in report["streams"]:
        if st["tier"] != "host_cache" or not st["analysis"]["n_gets"]:
            continue
        rep = st["replay"]
        lines.append(
            f"  [{st['label']} r{st['rank']}] {st['analysis']['n_gets']} gets"
            f" | hit rate deployed {rep['deployed']['hit_rate']:.1%}"
            f" lru {rep['lru']['hit_rate']:.1%}"
            f" ewma {rep['ewma']['hit_rate']:.1%}"
            f" belady {rep['belady']['hit_rate']:.1%}"
            f" | premature evictions "
            f"{st['analysis']['eviction_audit']['reref_frac']:.1%}"
        )
    return "\n".join(lines)
