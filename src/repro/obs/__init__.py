"""Unified observability plane: span tracing + labeled metrics.

- ``repro.obs.trace`` — near-zero-overhead nestable span tracer with a
  Chrome-trace (Perfetto-viewable) exporter; no-op when disabled.
- ``repro.obs.metrics`` — one ``(name, rank, tier, phase)``-labeled
  registry with adapters over the existing stat ledgers and a
  serializable snapshot.
- ``repro.obs.validate`` — CLI + library checks for the exported
  artifacts (Chrome-trace schema, span-tree nesting, cross-ledger
  accounting invariants, cachescope replay reconciliation).
- ``repro.obs.cachescope`` — per-rank, per-tier cache access-trace
  recorder + analysis engine (reuse distances, Mattson hit-rate curves,
  eviction audit, offline policy replay with Belady bound).

See docs/observability.md for the taxonomy and usage.
"""
from . import cachescope, trace
from .cachescope import (
    CacheTraceRecorder,
    disable_recording,
    enable_recording,
    get_recorder,
)
from .metrics import MetricRegistry
from .trace import Tracer, disable_tracing, enable_tracing, get_tracer

__all__ = [
    "trace",
    "cachescope",
    "MetricRegistry",
    "Tracer",
    "enable_tracing",
    "disable_tracing",
    "get_tracer",
    "CacheTraceRecorder",
    "enable_recording",
    "disable_recording",
    "get_recorder",
]
