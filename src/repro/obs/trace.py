"""Near-zero-overhead span tracer with a Chrome-trace-event exporter.

The paper's headline numbers are *attribution* claims — caching cuts
total running time by up to 73%, and communication vs. computation
decomposes per rank. Reproducing those breakdowns needs a time
dimension on top of the counter ledgers: which phase (``fetch_rows``,
``all_to_all``, ``intersect_kernel``, ...) spent the wall clock, on
which rank, inside which enclosing unit of work.

Design constraints, in order:

1. **Disabled is the default and must cost ~nothing.** ``span()`` with
   no tracer installed is one module-global load, a ``None`` check, and
   a shared no-op context manager — no allocation, no clock read. The
   serving benchmark measures this (< 3% of end-to-end wall is the
   gate; in practice it is orders of magnitude below that).
2. **Spans are nestable and per-rank.** Rank maps to the Chrome trace
   ``tid``, so Perfetto renders one swim-lane per rank; nesting follows
   ``with`` scoping, which makes the exported span tree well-nested by
   construction (the validator checks it anyway).
3. **The export is a standard Chrome trace** (``{"traceEvents": [...]}``
   with ``ph: "X"`` complete events, microsecond timestamps): open it
   at https://ui.perfetto.dev or ``chrome://tracing`` unmodified.

Taxonomy (the phase names instrumentation uses — see
docs/observability.md for the full map):

    fetch_rows        rank-indexed row transport (``ShardedRuntime``)
    all_to_all        the SPMD collective + fused on-device intersect
    intersect_kernel  pair-intersection compute (loop mode, streaming)
    cache_admit       ClampiCache admission   (fine mode, instant)
    cache_evict       ClampiCache eviction    (fine mode, instant)
    cache_invalidate  coherence fanout through the runtime
    residency_patch   device-tier patch/evict/admit after a batch
    scheduler_flush   one microbatch drained through the engine
    delta_replay      coherence replay of a delta access stream
    stream_batch      one applied streaming update batch
    spmd_pack         host-side packing of one SPMD execution unit
    spmd_patch        resident-buffer drift patched to device (H2D)
    spmd_overlap_wait the reconciliation barrier of a pipelined unit

Fine mode (``enable_tracing(fine=True)``) additionally emits per-entry
``cache_admit``/``cache_evict`` instants from inside the cache — useful
for cache forensics, too hot to leave on for long runs.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "PHASES",
    "Tracer",
    "enable_tracing",
    "disable_tracing",
    "get_tracer",
    "span",
    "instant",
    "counter",
    "fine_enabled",
]

PHASES = (
    "fetch_rows",
    "all_to_all",
    "intersect_kernel",
    "cache_admit",
    "cache_evict",
    "cache_invalidate",
    "residency_patch",
    "scheduler_flush",
    "delta_replay",
    "stream_batch",
    "spmd_pack",
    "spmd_patch",
    "spmd_overlap_wait",
)


class _NullSpan:
    """Shared no-op context manager returned when tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> None:
        """No-op twin of ``_Span.set`` (late argument attachment)."""


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records a ``ph: "X"`` complete event on exit."""

    __slots__ = ("_tracer", "name", "rank", "cat", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, rank: int, cat: str,
                 args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.rank = rank
        self.cat = cat
        self.args = args

    def set(self, **args) -> None:
        """Attach arguments discovered mid-span (e.g. measured bytes)."""
        if self.args is None:
            self.args = {}
        self.args.update(args)

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self._tracer._complete(self, self._t0, t1)
        return False


class Tracer:
    """Collects trace events in memory; exports Chrome trace JSON.

    ``rank`` maps to ``tid`` (+1, so unranked events get lane 0); the
    single process is ``pid`` 0. Timestamps are microseconds relative
    to tracer creation (``perf_counter`` based, so durations are exact
    even though the origin is arbitrary).
    """

    def __init__(self, *, fine: bool = False):
        self.fine = bool(fine)
        self.events: List[dict] = []
        self._t0 = time.perf_counter()
        self._n_dropped = 0

    # ---------------- recording ----------------
    def _ts(self, t: float) -> float:
        return (t - self._t0) * 1e6  # microseconds, Chrome's unit

    def span(self, name: str, *, rank: int = -1, cat: str = "",
             **args) -> _Span:
        return _Span(self, name, int(rank), cat, args or None)

    def _complete(self, s: _Span, t0: float, t1: float) -> None:
        ev = {
            "name": s.name,
            "ph": "X",
            "ts": self._ts(t0),
            "dur": (t1 - t0) * 1e6,
            "pid": 0,
            "tid": s.rank + 1,
        }
        if s.cat:
            ev["cat"] = s.cat
        if s.args:
            ev["args"] = {k: _jsonable(v) for k, v in s.args.items()}
        self.events.append(ev)

    def instant(self, name: str, *, rank: int = -1, cat: str = "",
                **args) -> None:
        ev = {
            "name": name,
            "ph": "i",
            "ts": self._ts(time.perf_counter()),
            "pid": 0,
            "tid": int(rank) + 1,
            "s": "t",  # thread-scoped instant
        }
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        self.events.append(ev)

    def counter(self, name: str, value: float, *, rank: int = -1) -> None:
        self.events.append({
            "name": name,
            "ph": "C",
            "ts": self._ts(time.perf_counter()),
            "pid": 0,
            "tid": int(rank) + 1,
            "args": {name: float(value)},
        })

    # ---------------- aggregation ----------------
    def phase_totals(self) -> Dict[str, Dict[str, float]]:
        """Per-phase rollup over the complete ("X") events:
        ``{name: {"calls", "total_s", "bytes"}}`` — the time dimension
        the metric registry folds in (``metrics.fold_trace``)."""
        out: Dict[str, Dict[str, float]] = {}
        for ev in self.events:
            if ev.get("ph") != "X":
                continue
            d = out.setdefault(
                ev["name"], {"calls": 0.0, "total_s": 0.0, "bytes": 0.0}
            )
            d["calls"] += 1
            d["total_s"] += ev.get("dur", 0.0) * 1e-6
            args = ev.get("args") or {}
            for k, v in args.items():
                if k.endswith("bytes") and isinstance(v, (int, float)):
                    d["bytes"] += v
        return out

    # ---------------- export ----------------
    def to_chrome(self) -> dict:
        """The Chrome trace object (Perfetto/chrome://tracing format)."""
        meta = [
            {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": "repro"}},
            {"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": "global"}},
        ]
        for tid in sorted({ev["tid"] for ev in self.events}):
            if tid > 0:
                meta.append({
                    "name": "thread_name", "ph": "M", "pid": 0,
                    "tid": tid, "args": {"name": f"rank {tid - 1}"},
                })
        return {
            "traceEvents": meta + self.events,
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs.trace"},
        }

    def export(self, path: str) -> None:
        """Write the trace; open the file at https://ui.perfetto.dev."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    def __len__(self) -> int:
        return len(self.events)


def _jsonable(v):
    """Span args must survive json.dump: coerce numpy scalars etc."""
    if isinstance(v, (str, bool)) or v is None:
        return v
    if isinstance(v, float):
        return v
    try:
        return int(v)
    except (TypeError, ValueError):
        return str(v)


# --------------------------------------------------------------------------
# Module-level switchboard: the instrumentation hooks call these. With no
# tracer installed, span() costs one global load + None check + returning
# the shared _NULL_SPAN — the near-zero-overhead contract.
# --------------------------------------------------------------------------
_tracer: Optional[Tracer] = None


def enable_tracing(*, fine: bool = False) -> Tracer:
    """Install (and return) a fresh global tracer."""
    global _tracer
    _tracer = Tracer(fine=fine)
    return _tracer


def disable_tracing() -> Optional[Tracer]:
    """Remove the global tracer; returns it (events intact) if any."""
    global _tracer
    t, _tracer = _tracer, None
    return t


def get_tracer() -> Optional[Tracer]:
    return _tracer


def span(name: str, *, rank: int = -1, cat: str = "", **args):
    """A context manager timing one phase (no-op when disabled)."""
    t = _tracer
    if t is None:
        return _NULL_SPAN
    return t.span(name, rank=rank, cat=cat, **args)


def instant(name: str, *, rank: int = -1, cat: str = "", **args) -> None:
    t = _tracer
    if t is not None:
        t.instant(name, rank=rank, cat=cat, **args)


def counter(name: str, value: float, *, rank: int = -1) -> None:
    t = _tracer
    if t is not None:
        t.counter(name, value, rank=rank)


def fine_enabled() -> bool:
    """True iff a tracer is installed AND fine-grained (per-cache-entry)
    events were requested — the gate in the cache hot paths."""
    t = _tracer
    return t is not None and t.fine
