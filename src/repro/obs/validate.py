"""Validate ``--trace`` / ``--metrics`` artifacts: schema + invariants.

CI runs this against every observability smoke::

    python -m repro.obs.validate --trace t.json --metrics m.json

Trace checks (Chrome trace event format):
  - top level is ``{"traceEvents": [...]}``
  - every event has ``name``/``ph``/``ts``/``pid``/``tid``; ``X``
    (complete) events also have ``dur >= 0``
  - per ``(pid, tid)`` lane, the ``X`` spans form a well-nested tree:
    any two spans are disjoint or one contains the other

Metric invariants (the cross-ledger accounting identities):
  - per rank and in total: ``local_reads + remote_reads == row_requests``
  - host-tier resolution is exhaustive: ``device_hits + cache_hits +
    cache_misses == remote_reads`` (hits + misses == row requests once
    local reads are netted out)
  - measured == modeled RMA traffic: when a ``CollectiveLedger`` was
    recorded, ``rma_rows_measured == rma_rows_modeled`` and
    ``rma_bytes_measured == rma_bytes_modeled`` (and the exported
    ``rma_agreement`` gauge is 1.0)
  - the placement gauges (``load_imbalance``, ``serve_matrix_skew``)
    are populated (> 0) whenever any rows were read

Cachescope checks (``--cachescope``, schema ``repro.obs.cachescope/v1``):
  - per stream: required keys, tier in {host_cache, device}, event
    arrays aligned
  - the replay-reconciliation invariant *recomputed from the raw
    events*: replaying the recorded trace under the deployed policy
    must reproduce the live stats deltas bit-exactly (host: gets/hits/
    misses/evictions/...; device: lookups/hits/misses/admits/evicts/
    patches) — not just trusting the stored ``reconciled`` flag
  - the stored Belady replay dominates every real policy's hits
  - Mattson spot checks (when present) all match direct simulation
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

__all__ = ["validate_trace", "validate_metrics", "validate_cachescope",
           "main"]

_REQUIRED_EVENT_KEYS = ("name", "ph", "pid", "tid")


# --------------------------------------------------------------------------
# Trace
# --------------------------------------------------------------------------

def validate_trace(trace: dict) -> List[str]:
    """Return a list of violations (empty == valid)."""
    bad: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["top-level 'traceEvents' list missing"]
    lanes: Dict[Tuple[int, int], List[Tuple[float, float, str]]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            bad.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        missing = [k for k in _REQUIRED_EVENT_KEYS if k not in ev]
        if ph != "M" and "ts" not in ev:  # metadata events carry no ts
            missing.append("ts")
        if missing:
            bad.append(f"event {i} ({ev.get('name')!r}): missing {missing}")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                bad.append(f"event {i} ({ev['name']!r}): bad dur {dur!r}")
                continue
            lanes.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ev["ts"]), float(ev["ts"]) + float(dur), ev["name"])
            )
    for (pid, tid), spans in lanes.items():
        bad.extend(
            f"lane (pid={pid}, tid={tid}): {msg}"
            for msg in _check_nesting(spans)
        )
    return bad


def _check_nesting(spans: List[Tuple[float, float, str]]) -> List[str]:
    """Well-nestedness on one lane: sorted by (start, -length), each
    span must be fully inside whichever open span it starts under."""
    bad: List[str] = []
    stack: List[Tuple[float, float, str]] = []
    for t0, t1, name in sorted(spans, key=lambda s: (s[0], s[0] - s[1])):
        while stack and stack[-1][1] <= t0:
            stack.pop()
        if stack and t1 > stack[-1][1]:
            bad.append(
                f"span {name!r} [{t0:.3f}, {t1:.3f}) overlaps "
                f"{stack[-1][2]!r} [{stack[-1][0]:.3f}, {stack[-1][1]:.3f})"
            )
            continue
        stack.append((t0, t1, name))
    return bad


# --------------------------------------------------------------------------
# Metrics
# --------------------------------------------------------------------------

def _counter_total(snap: dict, name: str, *, rank: Optional[int] = None,
                   tier: Optional[str] = None,
                   phase: Optional[str] = None) -> float:
    return sum(
        row["value"] for row in snap.get("counters", [])
        if row["name"] == name
        and (rank is None or row["rank"] == rank)
        and (tier is None or row["tier"] == tier)
        and (phase is None or row["phase"] == phase)
    )


def _has_counter(snap: dict, name: str) -> bool:
    return any(row["name"] == name for row in snap.get("counters", []))


def _gauge(snap: dict, name: str, *, rank: int = -1) -> Optional[float]:
    for row in snap.get("gauges", []):
        if row["name"] == name and row["rank"] == rank:
            return row["value"]
    return None


def validate_metrics(snap: dict) -> List[str]:
    """Return a list of invariant violations (empty == valid)."""
    bad: List[str] = []
    if snap.get("schema") != "repro.obs.metrics/v1":
        return [f"unknown snapshot schema {snap.get('schema')!r}"]

    ranks = sorted({
        row["rank"] for row in snap.get("counters", [])
        if row["tier"] == "host" and row["rank"] >= 0
    })
    for scope in ([None] + ranks if ranks else [None]):
        local = _counter_total(snap, "local_reads", rank=scope, tier="host")
        remote = _counter_total(snap, "remote_reads", rank=scope, tier="host")
        requests = _counter_total(snap, "row_requests", rank=scope,
                                  tier="host")
        label = "total" if scope is None else f"rank {scope}"
        if local + remote != requests:
            bad.append(
                f"{label}: local_reads + remote_reads != row_requests "
                f"({local:g} + {remote:g} != {requests:g})"
            )
        hits = _counter_total(snap, "cache_hits", rank=scope, tier="host")
        misses = _counter_total(snap, "cache_misses", rank=scope, tier="host")
        dev = _counter_total(snap, "device_hits", rank=scope, tier="host")
        if hits + misses + dev != remote:
            bad.append(
                f"{label}: cache hits + misses (+device) != remote row "
                f"requests ({hits:g} + {misses:g} + {dev:g} != {remote:g})"
            )

    cache_ranks = sorted({
        row["rank"] for row in snap.get("counters", [])
        if row["tier"] == "host_cache" and row["rank"] >= 0
    })
    for scope in [None] + cache_ranks:
        gets = _counter_total(snap, "gets", rank=scope, tier="host_cache")
        h = _counter_total(snap, "hits", rank=scope, tier="host_cache")
        m = _counter_total(snap, "misses", rank=scope, tier="host_cache")
        if h + m != gets:
            label = "total" if scope is None else f"rank {scope}"
            bad.append(
                f"host_cache {label}: hits + misses != gets "
                f"({h:g} + {m:g} != {gets:g})"
            )

    # measured-vs-modeled applies only when reconciliation was recorded
    # (model and measurement covering the same traffic — query-serving
    # SPMD). A bare CollectiveLedger (streaming SPMD, whose loop-path
    # counterpart reads the store directly) makes no such claim.
    agreement = _gauge(snap, "rma_agreement")
    if agreement is not None:
        for dim in ("rows", "bytes"):
            measured = _counter_total(snap, f"rma_{dim}_measured",
                                      tier="wire")
            modeled = _counter_total(snap, f"rma_{dim}_modeled", tier="wire")
            if measured != modeled:
                bad.append(
                    f"rma_{dim}: measured {measured:g} != modeled "
                    f"{modeled:g}"
                )
        if agreement != 1.0:
            bad.append(f"rma_agreement gauge is {agreement:g}, expected 1.0")

    # placement gauges ship with every runtime-backed snapshot (the
    # epoch driver has no ShardedRuntime, hence no host tier — skip)
    if ranks or _has_counter(snap, "row_requests"):
        total_reads = _counter_total(snap, "row_requests", tier="host")
        for g in ("load_imbalance", "serve_matrix_skew"):
            v = _gauge(snap, g)
            if v is None:
                bad.append(f"gauge {g!r} missing")
            elif total_reads > 0 and not v > 0:
                bad.append(f"gauge {g!r} not populated ({v!r}) despite "
                           f"{total_reads:g} row requests")
    return bad


# --------------------------------------------------------------------------
# Cachescope sidecar
# --------------------------------------------------------------------------

_HOST_STREAM_KEYS = ("tier", "rank", "label", "config", "events", "live",
                     "replay", "reconciled", "analysis")
_HOST_EVENT_KEYS = ("kinds", "keys", "sizes", "scores", "hits")


def validate_cachescope(doc: dict) -> List[str]:
    """Return a list of violations (empty == valid). Recomputes the
    deployed-policy replay from the raw events instead of trusting the
    stored ``reconciled`` flag."""
    from .cachescope import (
        DEVICE_COMPARE,
        HOST_COMPARE,
        SCHEMA,
        replay_device,
        replay_host,
    )

    bad: List[str] = []
    if doc.get("schema") != SCHEMA:
        return [f"unknown cachescope schema {doc.get('schema')!r}"]
    streams = doc.get("streams")
    if not isinstance(streams, list):
        return ["top-level 'streams' list missing"]
    for i, s in enumerate(streams):
        label = f"stream {i} ({s.get('label')!r} r{s.get('rank')})"
        missing = [k for k in _HOST_STREAM_KEYS if k not in s]
        if missing:
            bad.append(f"{label}: missing keys {missing}")
            continue
        tier = s["tier"]
        if tier not in ("host_cache", "device"):
            bad.append(f"{label}: unknown tier {tier!r}")
            continue
        if tier == "host_cache":
            ev = s["events"]
            miss_ev = [k for k in _HOST_EVENT_KEYS if k not in ev]
            if miss_ev:
                bad.append(f"{label}: events missing {miss_ev}")
                continue
            n = len(ev["kinds"])
            if not (len(ev["keys"]) == len(ev["sizes"])
                    == len(ev["scores"]) == len(ev["hits"]) == n):
                bad.append(f"{label}: event arrays misaligned")
                continue
            recomputed = replay_host(s, policy="deployed")
            compare = HOST_COMPARE
        else:
            recomputed = replay_device(s)
            compare = DEVICE_COMPARE
        live = s["live"]
        diffs = [
            f"{k}: live {int(live.get(k, 0))} != replay "
            f"{int(recomputed.get(k, 0))}"
            for k in compare
            if int(live.get(k, 0)) != int(recomputed.get(k, 0))
        ]
        if diffs:
            bad.append(f"{label}: replay does not reconcile "
                       f"({'; '.join(diffs)})")
        if not s["reconciled"]:
            bad.append(f"{label}: stored reconciled flag is false")
        if tier == "host_cache":
            replay = s["replay"]
            bel = replay.get("belady")
            if bel is None:
                bad.append(f"{label}: belady replay missing")
            else:
                for pol, rep in replay.items():
                    if pol != "belady" and rep.get("hits", 0) > bel["hits"]:
                        bad.append(
                            f"{label}: policy {pol!r} beats belady "
                            f"({rep['hits']} > {bel['hits']})"
                        )
            spot = s["analysis"].get("spot_checks") or []
            for sc in spot:
                if not sc["match"]:
                    bad.append(
                        f"{label}: mattson/direct mismatch at capacity "
                        f"{sc['capacity_bytes']} ({sc['mattson_hits']} != "
                        f"{sc['direct_hits']})"
                    )
    summ = doc.get("summary", {})
    if summ.get("all_reconciled") is not True and not any(
        "reconcile" in m for m in bad
    ):
        bad.append("summary.all_reconciled is not true")
    return bad


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate repro --trace/--metrics artifacts"
    )
    ap.add_argument("--trace", default=None, help="Chrome trace JSON path")
    ap.add_argument("--metrics", default=None, help="metrics snapshot path")
    ap.add_argument("--cachescope", default=None,
                    help="cachescope sidecar (.cachescope.json) path")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics and not args.cachescope:
        ap.error(
            "nothing to validate: pass --trace, --metrics, or --cachescope"
        )

    violations: List[str] = []
    if args.trace:
        with open(args.trace) as f:
            trace = json.load(f)
        v = validate_trace(trace)
        n_events = len(trace.get("traceEvents", []) or [])
        print(f"[validate] trace {args.trace}: {n_events} events, "
              f"{len(v)} violation(s)")
        violations += [f"trace: {m}" for m in v]
    if args.metrics:
        with open(args.metrics) as f:
            snap = json.load(f)
        v = validate_metrics(snap)
        print(f"[validate] metrics {args.metrics}: "
              f"{len(snap.get('counters', []))} counters, "
              f"{len(snap.get('gauges', []))} gauges, "
              f"{len(v)} violation(s)")
        violations += [f"metrics: {m}" for m in v]
    if args.cachescope:
        with open(args.cachescope) as f:
            doc = json.load(f)
        v = validate_cachescope(doc)
        n_streams = len(doc.get("streams", []) or [])
        print(f"[validate] cachescope {args.cachescope}: {n_streams} "
              f"stream(s), {len(v)} violation(s)")
        violations += [f"cachescope: {m}" for m in v]

    for m in violations:
        print(f"[validate]   FAIL {m}")
    print(f"[validate] {'FAIL' if violations else 'OK'}")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
