"""R-MAT synthetic graph generator (paper §IV-A).

Parameters follow the paper: ``a=0.57, b=c=0.19, d=0.05``; a graph with
scale ``x`` and edge factor ``y`` has ``2**x`` vertices and ``2**(x+y)``
edges (the paper writes 2^x * y; Graph500 convention is EF*2^x edges —
we follow #edges = EF * 2**scale, matching Table II's S21/EF16 => 33.6M).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "rmat_edges",
    "rmat_graph",
    "rmat_stream",
    "rmat_adversarial_stream",
]

A, B, C, D = 0.57, 0.19, 0.19, 0.05


def rmat_edges(
    scale: int,
    edge_factor: int,
    *,
    seed: int = 0,
    a: float = A,
    b: float = B,
    c: float = C,
) -> np.ndarray:
    """Vectorized R-MAT: one quadrant draw per (edge, level)."""
    n_edges = edge_factor << scale
    rng = np.random.default_rng(seed)
    src = np.zeros(n_edges, np.int64)
    dst = np.zeros(n_edges, np.int64)
    ab = a + b
    d_ = 1.0 - a - b - c
    for _ in range(scale):
        u = rng.random(n_edges)
        v = rng.random(n_edges)
        # factorized quadrant draw: src bit first (top half has mass a+b),
        # then dst bit conditioned on the half:
        #   top    (src_bit=0): P(dst_bit=1) = b / (a + b)
        #   bottom (src_bit=1): P(dst_bit=1) = d / (c + d)
        src_bit = u >= ab
        p_right = np.where(src_bit, d_ / (c + d_), b / ab)
        dst_bit = v < p_right
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return np.stack([src, dst], axis=1)


def rmat_graph(scale: int, edge_factor: int, *, seed: int = 0, undirected=True):
    """Edges -> simple CSR graph (self-loops/multi-edges removed)."""
    from ..core.csr import from_edges

    e = rmat_edges(scale, edge_factor, seed=seed)
    return from_edges(e, 1 << scale, undirected=undirected)


def rmat_stream(
    scale: int,
    edge_factor: int,
    *,
    batch_size: int,
    delete_frac: float = 0.0,
    seed: int = 0,
    shuffle: bool = True,
):
    """Yield ``EdgeBatch`` update batches replaying an R-MAT edge stream.

    The full R-MAT edge list (raw — duplicates and self-loops included, as
    a real ingest stream would carry them) arrives as insertions in
    ``batch_size``-op batches; with ``delete_frac > 0`` each batch also
    deletes that fraction of ops sampled from edges inserted by *earlier*
    batches (LiveJournal-style churn). Ops within a batch are shuffled so
    normalization sees interleaved inserts/deletes.
    """
    from ..streaming.updates import DELETE, INSERT, EdgeBatch

    edges = rmat_edges(scale, edge_factor, seed=seed)
    rng = np.random.default_rng(seed + 1)
    if shuffle:
        rng.shuffle(edges, axis=0)
    inserted: list = []  # canonical tuples from prior batches
    pos = 0
    while pos < edges.shape[0]:
        ins = edges[pos : pos + batch_size]
        pos += ins.shape[0]
        n_del = int(delete_frac * ins.shape[0])
        if n_del and inserted:
            pick = rng.integers(0, len(inserted), size=min(n_del, len(inserted)))
            dels = np.array([inserted[i] for i in pick], np.int64)
        else:
            dels = np.zeros((0, 2), np.int64)
        u = np.concatenate([ins[:, 0], dels[:, 0]])
        v = np.concatenate([ins[:, 1], dels[:, 1]])
        op = np.concatenate(
            [
                np.full(ins.shape[0], INSERT, np.int8),
                np.full(dels.shape[0], DELETE, np.int8),
            ]
        )
        if shuffle:
            perm = rng.permutation(u.size)
            u, v, op = u[perm], v[perm], op[perm]
        mask = ins[:, 0] != ins[:, 1]
        lo = np.minimum(ins[mask, 0], ins[mask, 1])
        hi = np.maximum(ins[mask, 0], ins[mask, 1])
        inserted.extend(zip(lo.tolist(), hi.tolist()))
        yield EdgeBatch(u=u, v=v, op=op)


def rmat_adversarial_stream(
    scale: int,
    edge_factor: int,
    *,
    batch_size: int,
    delete_frac: float = 0.25,
    hub_frac: float = 0.01,
    seed: int = 0,
):
    """Hub-targeted churn: the adversarial case for degree-scored caches.

    Inserts replay the R-MAT stream like ``rmat_stream``, but every
    delete targets an edge incident to a *current hub* — one of the top
    ``hub_frac`` fraction of vertices by (tracked) degree. Power-law
    hubs are exactly the vertices the degree-scored caches pin and the
    static residency set is built from, so hub-incident deletes maximize
    (a) stale resident rows and (b) top-C membership drift — the rebuild
    policy of ``refresh_static_degree_cache`` under its worst-case
    stream. R-MAT also keeps re-inserting edges at the same hubs, so the
    degree ranking keeps churning in both directions.
    """
    from ..streaming.updates import DELETE, INSERT, EdgeBatch

    n = 1 << scale
    edges = rmat_edges(scale, edge_factor, seed=seed)
    rng = np.random.default_rng(seed + 1)
    rng.shuffle(edges, axis=0)
    deg = np.zeros(n, np.int64)  # tracked over our own insert/delete ops
    # present edges: growable [M] key array + alive mask (rows are never
    # removed, only flagged; compacted when mostly dead) + a key set for
    # O(1) membership — candidate selection stays vectorized numpy.
    pres_keys = np.zeros(0, np.int64)
    alive = np.zeros(0, bool)
    present_set: set = set()
    n_hubs = max(1, int(hub_frac * n))
    pos = 0
    while pos < edges.shape[0]:
        ins = edges[pos : pos + batch_size]
        pos += ins.shape[0]
        mask = ins[:, 0] != ins[:, 1]
        ins_keys = (
            np.minimum(ins[mask, 0], ins[mask, 1]) * n
            + np.maximum(ins[mask, 0], ins[mask, 1])
        )
        n_del = int(delete_frac * ins.shape[0])
        dels = np.zeros((0, 2), np.int64)
        if n_del and alive.any():
            hubs = np.argpartition(deg, -n_hubs)[-n_hubs:]
            hub_mask = np.isin(pres_keys // n, hubs) | np.isin(
                pres_keys % n, hubs
            )
            # exclude edges this batch's slice re-inserts: a delete and
            # an insert of the same edge in one shuffled batch resolves
            # last-op-wins downstream, which would desync the tracker
            cand = np.flatnonzero(
                alive & hub_mask & ~np.isin(pres_keys, ins_keys)
            )
            if cand.size:
                pick = rng.choice(
                    cand, size=min(n_del, cand.size), replace=False
                )
                alive[pick] = False
                keys = pres_keys[pick]
                dels = np.stack([keys // n, keys % n], axis=1)
                present_set.difference_update(keys.tolist())
                np.add.at(deg, dels.ravel(), -1)
        fresh = np.array(
            sorted({int(k) for k in ins_keys.tolist()} - present_set),
            np.int64,
        )
        if fresh.size:
            present_set.update(fresh.tolist())
            pres_keys = np.concatenate([pres_keys, fresh])
            alive = np.concatenate([alive, np.ones(fresh.size, bool)])
            np.add.at(deg, np.concatenate([fresh // n, fresh % n]), 1)
        if alive.size > 64 and np.count_nonzero(alive) < alive.size // 2:
            pres_keys, alive = pres_keys[alive], alive[alive]
        u = np.concatenate([ins[:, 0], dels[:, 0]])
        v = np.concatenate([ins[:, 1], dels[:, 1]])
        op = np.concatenate(
            [
                np.full(ins.shape[0], INSERT, np.int8),
                np.full(dels.shape[0], DELETE, np.int8),
            ]
        )
        perm = rng.permutation(u.size)
        yield EdgeBatch(u=u[perm], v=v[perm], op=op[perm])
