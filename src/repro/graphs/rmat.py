"""R-MAT synthetic graph generator (paper §IV-A).

Parameters follow the paper: ``a=0.57, b=c=0.19, d=0.05``; a graph with
scale ``x`` and edge factor ``y`` has ``2**x`` vertices and ``2**(x+y)``
edges (the paper writes 2^x * y; Graph500 convention is EF*2^x edges —
we follow #edges = EF * 2**scale, matching Table II's S21/EF16 => 33.6M).
"""
from __future__ import annotations

import numpy as np

__all__ = ["rmat_edges", "rmat_graph", "rmat_stream"]

A, B, C, D = 0.57, 0.19, 0.19, 0.05


def rmat_edges(
    scale: int,
    edge_factor: int,
    *,
    seed: int = 0,
    a: float = A,
    b: float = B,
    c: float = C,
) -> np.ndarray:
    """Vectorized R-MAT: one quadrant draw per (edge, level)."""
    n_edges = edge_factor << scale
    rng = np.random.default_rng(seed)
    src = np.zeros(n_edges, np.int64)
    dst = np.zeros(n_edges, np.int64)
    ab = a + b
    d_ = 1.0 - a - b - c
    for _ in range(scale):
        u = rng.random(n_edges)
        v = rng.random(n_edges)
        # factorized quadrant draw: src bit first (top half has mass a+b),
        # then dst bit conditioned on the half:
        #   top    (src_bit=0): P(dst_bit=1) = b / (a + b)
        #   bottom (src_bit=1): P(dst_bit=1) = d / (c + d)
        src_bit = u >= ab
        p_right = np.where(src_bit, d_ / (c + d_), b / ab)
        dst_bit = v < p_right
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return np.stack([src, dst], axis=1)


def rmat_graph(scale: int, edge_factor: int, *, seed: int = 0, undirected=True):
    """Edges -> simple CSR graph (self-loops/multi-edges removed)."""
    from ..core.csr import from_edges

    e = rmat_edges(scale, edge_factor, seed=seed)
    return from_edges(e, 1 << scale, undirected=undirected)


def rmat_stream(
    scale: int,
    edge_factor: int,
    *,
    batch_size: int,
    delete_frac: float = 0.0,
    seed: int = 0,
    shuffle: bool = True,
):
    """Yield ``EdgeBatch`` update batches replaying an R-MAT edge stream.

    The full R-MAT edge list (raw — duplicates and self-loops included, as
    a real ingest stream would carry them) arrives as insertions in
    ``batch_size``-op batches; with ``delete_frac > 0`` each batch also
    deletes that fraction of ops sampled from edges inserted by *earlier*
    batches (LiveJournal-style churn). Ops within a batch are shuffled so
    normalization sees interleaved inserts/deletes.
    """
    from ..streaming.updates import DELETE, INSERT, EdgeBatch

    edges = rmat_edges(scale, edge_factor, seed=seed)
    rng = np.random.default_rng(seed + 1)
    if shuffle:
        rng.shuffle(edges, axis=0)
    inserted: list = []  # canonical tuples from prior batches
    pos = 0
    while pos < edges.shape[0]:
        ins = edges[pos : pos + batch_size]
        pos += ins.shape[0]
        n_del = int(delete_frac * ins.shape[0])
        if n_del and inserted:
            pick = rng.integers(0, len(inserted), size=min(n_del, len(inserted)))
            dels = np.array([inserted[i] for i in pick], np.int64)
        else:
            dels = np.zeros((0, 2), np.int64)
        u = np.concatenate([ins[:, 0], dels[:, 0]])
        v = np.concatenate([ins[:, 1], dels[:, 1]])
        op = np.concatenate(
            [
                np.full(ins.shape[0], INSERT, np.int8),
                np.full(dels.shape[0], DELETE, np.int8),
            ]
        )
        if shuffle:
            perm = rng.permutation(u.size)
            u, v, op = u[perm], v[perm], op[perm]
        mask = ins[:, 0] != ins[:, 1]
        lo = np.minimum(ins[mask, 0], ins[mask, 1])
        hi = np.maximum(ins[mask, 0], ins[mask, 1])
        inserted.extend(zip(lo.tolist(), hi.tolist()))
        yield EdgeBatch(u=u, v=v, op=op)
