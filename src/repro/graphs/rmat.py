"""R-MAT synthetic graph generator (paper §IV-A).

Parameters follow the paper: ``a=0.57, b=c=0.19, d=0.05``; a graph with
scale ``x`` and edge factor ``y`` has ``2**x`` vertices and ``2**(x+y)``
edges (the paper writes 2^x * y; Graph500 convention is EF*2^x edges —
we follow #edges = EF * 2**scale, matching Table II's S21/EF16 => 33.6M).
"""
from __future__ import annotations

import numpy as np

__all__ = ["rmat_edges", "rmat_graph"]

A, B, C, D = 0.57, 0.19, 0.19, 0.05


def rmat_edges(
    scale: int,
    edge_factor: int,
    *,
    seed: int = 0,
    a: float = A,
    b: float = B,
    c: float = C,
) -> np.ndarray:
    """Vectorized R-MAT: one quadrant draw per (edge, level)."""
    n_edges = edge_factor << scale
    rng = np.random.default_rng(seed)
    src = np.zeros(n_edges, np.int64)
    dst = np.zeros(n_edges, np.int64)
    ab = a + b
    d_ = 1.0 - a - b - c
    for _ in range(scale):
        u = rng.random(n_edges)
        v = rng.random(n_edges)
        # factorized quadrant draw: src bit first (top half has mass a+b),
        # then dst bit conditioned on the half:
        #   top    (src_bit=0): P(dst_bit=1) = b / (a + b)
        #   bottom (src_bit=1): P(dst_bit=1) = d / (c + d)
        src_bit = u >= ab
        p_right = np.where(src_bit, d_ / (c + d_), b / ab)
        dst_bit = v < p_right
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return np.stack([src, dst], axis=1)


def rmat_graph(scale: int, edge_factor: int, *, seed: int = 0, undirected=True):
    """Edges -> simple CSR graph (self-loops/multi-edges removed)."""
    from ..core.csr import from_edges

    e = rmat_edges(scale, edge_factor, seed=seed)
    return from_edges(e, 1 << scale, undirected=undirected)
