"""Neighbor sampler for sampled GNN training (minibatch_lg shape).

GraphSAGE-style fanout sampling (fanout (15, 10) for the assigned shape):
for a seed batch of nodes, sample up to ``fanout[0]`` neighbors per seed,
then ``fanout[1]`` per frontier node, producing a fixed-shape (padded)
block: seeds, per-hop edge lists (src, dst) and the unique node set with
an index mapping — everything static-shape so the GNN step jit-compiles
once.

This is a *real* sampler (the assignment calls it out): it operates on a
host CSR with reservoir-free uniform sampling via ``np.random.Generator``
and returns numpy arrays ready to donate to the device step.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from ..core.csr import CSRGraph

__all__ = ["SampledBlock", "NeighborSampler"]


@dataclasses.dataclass
class SampledBlock:
    """Fixed-shape sampled subgraph for one minibatch.

    nodes:      [n_max] global ids (padded with -1)
    n_nodes:    scalar, number of valid nodes
    edge_src:   [e_max] local indices into ``nodes`` (padded with n_max-1)
    edge_dst:   [e_max] local indices (message direction src -> dst)
    edge_mask:  [e_max] bool
    seeds_local:[batch] local indices of the seed nodes (output rows)
    """

    nodes: np.ndarray
    n_nodes: int
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    seeds_local: np.ndarray


class NeighborSampler:
    def __init__(
        self,
        csr: CSRGraph,
        fanout: Sequence[int] = (15, 10),
        *,
        seed: int = 0,
    ):
        self.csr = csr
        self.fanout = tuple(fanout)
        self.rng = np.random.default_rng(seed)

    def max_sizes(self, batch: int) -> Tuple[int, int]:
        """Static (n_max, e_max) bounds for a given seed-batch size."""
        n_max = batch
        e_max = 0
        frontier = batch
        for f in self.fanout:
            e_max += frontier * f
            frontier *= f
            n_max += frontier
        return n_max, e_max

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        batch = seeds.shape[0]
        n_max, e_max = self.max_sizes(batch)
        nodes = list(seeds.astype(np.int64))
        index = {int(v): i for i, v in enumerate(nodes)}
        srcs: list[int] = []
        dsts: list[int] = []
        frontier = list(seeds.astype(np.int64))
        for f in self.fanout:
            nxt: list[int] = []
            for v in frontier:
                row = self.csr.row(int(v))
                if row.size == 0:
                    continue
                take = row if row.size <= f else self.rng.choice(
                    row, size=f, replace=False
                )
                for u in take:
                    u = int(u)
                    if u not in index:
                        index[u] = len(nodes)
                        nodes.append(u)
                        nxt.append(u)
                    # message u -> v
                    srcs.append(index[u])
                    dsts.append(index[int(v)])
            frontier = nxt
        n_nodes = len(nodes)
        nodes_arr = np.full(n_max, -1, np.int64)
        nodes_arr[:n_nodes] = nodes
        e = len(srcs)
        edge_src = np.full(e_max, n_max - 1, np.int32)
        edge_dst = np.full(e_max, n_max - 1, np.int32)
        mask = np.zeros(e_max, bool)
        edge_src[:e] = srcs
        edge_dst[:e] = dsts
        mask[:e] = True
        seeds_local = np.arange(batch, dtype=np.int32)
        return SampledBlock(
            nodes=nodes_arr,
            n_nodes=n_nodes,
            edge_src=edge_src,
            edge_dst=edge_dst,
            edge_mask=mask,
            seeds_local=seeds_local,
        )
