"""Graph dataset stand-ins (paper §IV-A, Table II).

The container is offline, so SNAP/KONECT graphs are represented by
*synthetic generators with matching shape statistics*: a power-law
(Barabási–Albert-style preferential attachment) generator for the social
graphs and the R-MAT generator for the synthetic rows of Table II. Each
entry records the real graph's (|V|, |E|) so benchmarks can report the
scale they stand in for.

Also provides the assigned GNN-architecture graph shapes:
  full_graph_sm  (Cora:      n=2708,    m=10556,  d_feat=1433)
  minibatch_lg   (Reddit:    n=232965,  m=114.6M, batch=1024, fanout 15-10)
  ogb_products   (n=2449029, m=61.9M,   d_feat=100)
  molecule       (n=30, m=64, batch=128)
For the two large ones, full edge structure is never materialized host-side
in tests — the dry-run uses ShapeDtypeStructs and the samplers draw local
neighborhoods lazily.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.csr import CSRGraph, from_edges

__all__ = ["GraphSpec", "GRAPHS", "powerlaw_graph", "uniform_graph", "get"]


@dataclasses.dataclass(frozen=True)
class GraphSpec:
    name: str
    n: int
    m: int
    directed: bool
    kind: str  # 'powerlaw' | 'uniform' | 'rmat'
    scale_stand_in: int  # scale for generators when materialized


# Real-graph rows of Table II (sizes recorded; materialized via stand-ins).
GRAPHS = {
    "orkut": GraphSpec("SNAP-Orkut", 3_000_000, 117_200_000, False, "powerlaw", 17),
    "livejournal": GraphSpec("SNAP-LiveJournal", 4_000_000, 34_700_000, False, "powerlaw", 17),
    "livejournal1": GraphSpec("SNAP-LiveJournal1", 4_800_000, 69_000_000, True, "powerlaw", 17),
    "skitter": GraphSpec("SNAP-Skitter", 1_700_000, 11_100_000, False, "powerlaw", 16),
    "uk-2005": GraphSpec("uk-2005", 39_500_000, 936_400_000, True, "powerlaw", 18),
    "wiki-en": GraphSpec("wiki-en", 13_600_000, 437_200_000, True, "powerlaw", 18),
    "facebook_circles": GraphSpec("ego-Facebook", 4_039, 88_234, False, "powerlaw", 12),
}


def powerlaw_graph(n: int, avg_deg: int, *, seed: int = 0) -> CSRGraph:
    """Preferential-attachment-flavored power-law graph (vectorized).

    Repeated-degree sampling: draw edge endpoints with probability
    proportional to a Zipf-ish weight, giving a heavy-tailed degree
    distribution comparable to the SNAP social graphs.
    """
    rng = np.random.default_rng(seed)
    m = n * avg_deg // 2
    # Zipf weights over a random permutation so hubs are spread across the
    # id range (the paper random-relabels degree-ordered inputs; we bake
    # the equivalent in).
    w = 1.0 / np.arange(1, n + 1) ** 0.75
    w /= w.sum()
    perm = rng.permutation(n)
    src = perm[rng.choice(n, size=m, p=w)]
    dst = perm[rng.choice(n, size=m, p=w)]
    return from_edges(np.stack([src, dst], 1), n, undirected=True)


def uniform_graph(n: int, avg_deg: int, *, seed: int = 0) -> CSRGraph:
    """Uniform (Erdős–Rényi-style) graph — the flat-degree control of Fig. 4."""
    rng = np.random.default_rng(seed)
    m = n * avg_deg // 2
    e = rng.integers(0, n, size=(m, 2))
    return from_edges(e, n, undirected=True)


def get(name: str, *, max_n: int = 1 << 14, seed: int = 0) -> CSRGraph:
    """Materialize a (scaled-down) stand-in for a named Table II graph."""
    spec = GRAPHS[name]
    n = min(spec.n, max_n)
    avg = max(2, min(spec.m // max(spec.n, 1) * 2, 64))
    if spec.kind == "uniform":
        return uniform_graph(n, avg, seed=seed)
    return powerlaw_graph(n, avg, seed=seed)
