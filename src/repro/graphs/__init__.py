from . import rmat, datasets, sampler  # noqa: F401
